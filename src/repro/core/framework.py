"""The core framework — runs and controls the processing chain
(paper §III.D, Figs 5–7).

Phases:
  1. **check**  — the plugin-list check (delegated to ProcessList.check),
  2. **setup**  — loaders create lazy datasets; each processing plugin is
     "plugged in": its PluginData views are attached, its ``setup``
     describes the out_datasets, and the framework completes them by
     attaching backing storage via the transport (Fig 5),
  3. **main**   — per plugin: pre_process → frame loop (via transport) →
     post_process (MPI-barrier semantics = blocking jit), then the
     out_dataset *replaces* any in_dataset of the same name (Fig 6 (i)),
  4. **finalise** — savers persist surviving datasets; a NeXus-style JSON
     manifest links every intermediate file (paper §III.A).

Fusion (beyond paper): consecutive 1-in/1-out plugins that share a
driver are compiled as ONE jit on the sharded transport, so the
pattern-transition collective is scheduled by XLA inside a single
program instead of a host round-trip between plugins.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any

import numpy as np

from .dataset import DataSet
from .plugin import BaseLoader, BasePlugin, BaseSaver, PluginData
from .process_list import ProcessList
from .profiler import Profiler
from .transport import (ChunkedFileTransport, InMemoryTransport,
                        ShardedTransport, Transport)


class _StreamState:
    """Arrival-driven execution state for one PluginRunner.

    Tracks the growing root dataset, how far each *windowed* plugin has
    processed along the arrival axis, and which datasets downstream of
    the root also grow (window outputs).  Plugins are classified once at
    :meth:`PluginRunner.enable_streaming`:

    * ``window`` — every streaming input slices along the arrival axis
      with ``n_frames == 1`` and every output carries the axis at full
      size in its slice dims: the plugin can run incrementally over
      newly-arrived slabs (host numpy, bit-identical to the batch frame
      loop because each frame is processed independently).
    * ``barrier`` — the arrival axis is a core dim of some input (e.g.
      sinogram-space plugins need all angles), the group is fused, or
      the plugin consumes no streaming data: it runs exactly once, via
      the normal transport path, when all its streaming inputs are
      complete.
    """

    def __init__(self, dataset: DataSet, axis_index: int, axis_label: str):
        self.dataset = dataset
        self.axis_index = axis_index
        self.axis_label = axis_label
        self.total = dataset.shape[axis_index]
        self.ingested = 0
        self.eof = False
        #: (group, plugin_idx) -> "window" | "barrier"
        self.kind: dict[tuple[int, int], str] = {}
        #: (group, plugin_idx) -> frames consumed (window plugins only)
        self.cursors: dict[tuple[int, int], int] = {}
        #: window plugins whose pre_process already ran
        self.begun: set[tuple[int, int]] = set()
        #: id(dataset) -> arrival-axis index, for every streaming dataset
        self.axes: dict[int, int] = {}

    @property
    def complete(self) -> bool:
        return self.ingested >= self.total


class PluginRunner:
    def __init__(self, process_list: ProcessList,
                 transport: Transport | None = None,
                 profiler: Profiler | None = None,
                 fuse: bool = False,
                 output_dir: str | None = None):
        self.process_list = process_list
        self.transport = transport or InMemoryTransport()
        self.profiler = profiler or Profiler()
        self.fuse = fuse and isinstance(self.transport, ShardedTransport)
        self.output_dir = output_dir
        #: name -> DataSet currently available for processing
        self.datasets: dict[str, DataSet] = {}
        #: every dataset ever produced (for the NeXus-style manifest)
        self.lineage: list[DataSet] = []
        self._prepared = False
        self._groups: list[list[BasePlugin]] = []
        self._step_i = 0
        self._in_step = False
        #: arrival-driven execution state (enable_streaming); None = batch
        self._stream: _StreamState | None = None

    # ------------------------------------------------------------------
    def run(self) -> dict[str, DataSet]:
        self.prepare()
        try:
            while self.step():
                pass
            self.finalise()
        except BaseException:
            # a mid-chain plugin failure must not leak open ChunkedFile
            # handles — finalise() normally closes the transport, so on
            # the error path close it best-effort before re-raising
            try:
                self.transport.close()
            except Exception:       # noqa: BLE001 — original error wins
                pass
            raise
        return self.datasets

    # -- resumable stepping interface (service layer) -------------------
    def prepare(self) -> "PluginRunner":
        """Check the process list and run the setup phase; after this the
        runner is a sequence of ``n_steps`` resumable plugin steps."""
        if self._prepared:
            return self
        self.process_list.check()
        self._loaders, self._processors, self._savers = self._split()
        self._setup_phase(self._loaders, self._processors, self._savers)
        self._groups = (self._fusion_groups(self._processors) if self.fuse
                        else [[p] for p in self._processors])
        self._compute_liveness()
        self._step_i = 0
        self._prepared = True
        return self

    @property
    def n_steps(self) -> int:
        return len(self._groups)

    @property
    def current_step(self) -> int:
        return self._step_i

    def step_labels(self) -> list[str]:
        return ["+".join(p.name for p in g) for g in self._groups]

    def result_names(self) -> list[str]:
        """Names of the datasets consumed by savers — the chain's
        outputs, in saver order.  These are what a service result
        endpoint should offer for download.  Requires :meth:`prepare`."""
        if not self._prepared:
            raise RuntimeError("result_names before prepare()")
        names: list[str] = []
        for sv in self._savers:
            for n in sv.in_dataset_names:
                if n not in names:
                    names.append(n)
        return names

    # -- dataset liveness ----------------------------------------------
    def _compute_liveness(self) -> None:
        """Per-dataset-object liveness over the step sequence: which step
        produces each dataset version and which step consumes it LAST.
        Savers count as consumers at the sentinel step ``n_steps`` (their
        datasets must survive the whole chain).  Donation and the
        checkpointer both read this instead of guessing."""
        producer: dict[int, int] = {}
        last_use: dict[int, int] = {}
        #: (consume_step, producer_step, dataset name) per use — producer
        #: is -1 for loader-created datasets
        uses: list[tuple[int, int, str]] = []
        for g, group in enumerate(self._groups):
            for p in group:
                for pd in p.in_data:
                    ds = pd.dataset
                    last_use[id(ds)] = g
                    uses.append((g, producer.get(id(ds), -1), ds.name))
                for pd in p.out_data:
                    producer[id(pd.dataset)] = g
        n = len(self._groups)
        for sv in self._savers:
            for name in sv.in_dataset_names:
                ds = self._final.get(name)
                if ds is not None:
                    last_use[id(ds)] = n
                    uses.append((n, producer.get(id(ds), -1), name))
        self._last_use = last_use
        self._uses = uses
        #: id(dataset) -> producing step (-1 / absent: loader-created)
        self._producer_of = producer

    def required_live_names(self, step: int) -> set[str]:
        """Dataset names a resume from ``step`` completed steps must get
        back from a checkpoint: consumed at some step >= ``step`` (savers
        count as consuming at ``n_steps``) but produced BEFORE ``step`` —
        i.e. by a plugin that will not run again, or by a loader.

        Window-awareness (streaming): while a stream is mid-flight the
        step cursor is pinned at the first incomplete group, so this set
        always contains the growing root dataset; windowed plugins ahead
        of the cursor do NOT pin their partial outputs here because a
        restore resets their window cursors to 0 and recomputes them
        from the restored prefix (deterministic per-frame kernels make
        that bit-identical)."""
        return {name for g, prod, name in self._uses
                if g >= step and prod < step}

    def begin_step(self) -> list[BasePlugin] | None:
        """Rebind the next group's in_data to the live dataset registry
        and run pre_process.  Returns the group, or None when exhausted.
        The caller must execute the group (via the transport) and then
        call :meth:`complete_step` — this split lets the service layer
        batch identical steps from several runners into one call."""
        if not self._prepared:
            self.prepare()
        if self._in_step:
            raise RuntimeError("begin_step called twice without "
                               "complete_step")
        if self._step_i >= len(self._groups):
            return None
        group = self._groups[self._step_i]
        devices = getattr(getattr(self.transport, "mesh", None), "size", 1)
        for p in group:
            for pd in p.in_data:
                if pd.dataset.name in self.datasets:
                    pd.dataset = self.datasets[pd.dataset.name]
                # donation hint: this step may consume the buffer only if
                # no later step (or saver) reads this dataset version
                lu = self._last_use.get(id(pd.dataset))
                pd.last_use = lu is not None and lu <= self._step_i
            with self.profiler.timer(p.name, "pre", devices):
                p.pre_process()
        self._in_step = True
        return group

    def complete_step(self) -> None:
        """Post-process + replacement semantics for the group started by
        :meth:`begin_step`, then advance the step cursor."""
        if not self._in_step:
            raise RuntimeError("complete_step without begin_step")
        devices = getattr(getattr(self.transport, "mesh", None), "size", 1)
        for p in self._groups[self._step_i]:
            with self.profiler.timer(p.name, "post", devices):
                p.post_process()
            self._replace(p)
        self._in_step = False
        self._step_i += 1

    def step(self) -> bool:
        """Run one plugin (or fused group).  Returns False when the chain
        is exhausted."""
        group = self.begin_step()
        if group is None:
            return False
        devices = getattr(getattr(self.transport, "mesh", None), "size", 1)
        if len(group) == 1:
            p = group[0]
            # cost analysis (when the transport offers it) runs BEFORE
            # the timer so its one-off AOT compile never pollutes the
            # process span it annotates
            cost = (self.transport.plugin_cost(p)
                    if hasattr(self.transport, "plugin_cost") else None)
            with self.profiler.timer(p.name, "process", devices,
                                     **(cost or {})):
                self.transport.run_plugin(p)
        else:
            label = "+".join(p.name for p in group)
            with self.profiler.timer(label, "process", devices, fused=True):
                self.transport.run_fused(group)
        self.complete_step()
        return True

    def skip_to(self, step: int,
                datasets: dict[str, Any] | None = None) -> None:
        """Resume support: mark the first ``step`` groups as already done
        (replaying their replacement semantics WITHOUT executing them) and
        restore the surviving datasets' contents from ``datasets``
        (name -> host array, e.g. loaded from a checkpoint)."""
        self.prepare()
        if self._step_i != 0:
            raise RuntimeError("skip_to on a runner that already stepped")
        if not 0 <= step <= len(self._groups):
            raise ValueError(f"step {step} outside 0..{len(self._groups)}")
        for group in self._groups[:step]:
            for p in group:
                self._replace(p)
        self._step_i = step
        for name, arr in (datasets or {}).items():
            if name not in self.datasets:
                continue
            ds = self.datasets[name]
            if hasattr(ds.backing, "write_all"):
                ds.backing.write_all(arr)
            else:
                ds.backing = arr

    def finalise(self) -> None:
        if self._step_i < len(self._groups):
            raise RuntimeError(
                f"finalise at step {self._step_i}/{len(self._groups)}")
        if self._stream is not None and not self._stream.complete:
            raise RuntimeError(
                f"finalise mid-stream at frame "
                f"{self._stream.ingested}/{self._stream.total}")
        self._finalise(self._savers)

    # -- streaming (arrival-driven) execution ---------------------------
    @property
    def streaming(self) -> bool:
        return self._stream is not None

    def _require_stream(self) -> _StreamState:
        if self._stream is None:
            raise RuntimeError("streaming not enabled on this runner "
                               "(call enable_streaming first)")
        return self._stream

    @staticmethod
    def _ensure_writable(ds: DataSet) -> None:
        """Swap a lazy loader thunk / unallocated backing for writable
        host storage that :meth:`feed` / windows can fill in place.
        ChunkedFile backings already support region writes and stay."""
        b = ds.backing
        if b is None or (callable(b) and not hasattr(b, "shape")):
            ds.backing = np.zeros(ds.shape, dtype=ds.dtype)

    @staticmethod
    def _read_slab(ds: DataSet, axis: int, lo: int, hi: int) -> np.ndarray:
        region = tuple(slice(lo, hi) if d == axis else slice(0, s)
                       for d, s in enumerate(ds.shape))
        b = ds.materialise()
        if hasattr(b, "read") and hasattr(b, "chunks"):   # ChunkedFile
            return b.read(region)
        return np.asarray(b[region])

    @staticmethod
    def _write_slab(ds: DataSet, axis: int, lo: int, hi: int,
                    values: np.ndarray) -> None:
        region = tuple(slice(lo, hi) if d == axis else slice(0, s)
                       for d, s in enumerate(ds.shape))
        b = ds.materialise()
        if hasattr(b, "write") and hasattr(b, "chunks"):  # ChunkedFile
            b.write(region, values)
        else:
            b[region] = values

    def enable_streaming(self, dataset: str | None = None,
                         axis: str | None = None) -> "PluginRunner":
        """Open this runner against a *growing* loader dataset: frames
        arrive via :meth:`feed`, :meth:`pump` executes whatever the
        arrived prefix allows, and the chain completes once every frame
        has landed.  ``dataset`` defaults to the sole loader-created
        dataset, ``axis`` to its first axis label (the acquisition
        axis).  Idempotent; must be called before any step runs."""
        self.prepare()
        if self._stream is not None:
            if dataset and self._stream.dataset.name != dataset:
                raise ValueError(
                    f"streaming already enabled on "
                    f"{self._stream.dataset.name!r}, not {dataset!r}")
            return self
        if self._step_i != 0 or self._in_step:
            raise RuntimeError("enable_streaming on a runner that "
                               "already stepped")
        if dataset is None:
            roots = [d for d in self.datasets.values() if not d.produced_by]
            if len(roots) != 1:
                raise ValueError(
                    f"enable_streaming needs an explicit dataset name "
                    f"(loader created {[d.name for d in roots]})")
            ds = roots[0]
        else:
            if dataset not in self.datasets:
                raise KeyError(f"no dataset {dataset!r} to stream into")
            ds = self.datasets[dataset]
        axis = axis or ds.axis_labels[0]
        ai = ds.label_index(axis)
        self._ensure_writable(ds)
        ds.available_extent = 0
        ds.stream_axis = axis
        st = _StreamState(ds, ai, axis)
        st.axes[id(ds)] = ai
        for g, group in enumerate(self._groups):
            for j, p in enumerate(group):
                s_ins = [pd for pd in p.in_data
                         if id(pd.dataset) in st.axes]
                if not s_ins:
                    st.kind[(g, j)] = "barrier"   # no stream dependency
                    continue
                windowed = len(group) == 1 and bool(p.out_data)
                for pd in s_ins:
                    a_in = st.axes[id(pd.dataset)]
                    try:
                        pat = pd.pattern
                    except KeyError:
                        pat = None
                    if pat is None or a_in not in pat.slice_dims \
                            or pd.n_frames != 1:
                        windowed = False
                out_axes = []
                for pd in p.out_data:
                    od = pd.dataset
                    if axis not in od.axis_labels:
                        windowed = False
                        break
                    oi = od.label_index(axis)
                    try:
                        opat = pd.dataset.get_pattern(pd.pattern_name)
                    except KeyError:
                        opat = None
                    if od.shape[oi] != st.total or opat is None \
                            or oi not in opat.slice_dims:
                        windowed = False
                        break
                    out_axes.append((od, oi))
                if windowed:
                    st.kind[(g, j)] = "window"
                    st.cursors[(g, j)] = 0
                    for od, oi in out_axes:
                        self._ensure_writable(od)
                        od.available_extent = 0
                        od.stream_axis = axis
                        st.axes[id(od)] = oi
                else:
                    st.kind[(g, j)] = "barrier"
        self._stream = st
        return self

    def feed(self, frames: Any, start: int) -> int:
        """Append ``frames`` (arrival axis LEADING) at frame ``start``.
        Frames must arrive contiguously and in order — the service layer
        maps violations to HTTP 409.  Returns the new watermark."""
        st = self._require_stream()
        ds = st.dataset
        arr = np.asarray(frames)
        if arr.ndim != ds.ndim:
            raise ValueError(
                f"feed: frames are {arr.ndim}-d, dataset {ds.name!r} "
                f"is {ds.ndim}-d")
        if st.axis_index != 0:
            arr = np.moveaxis(arr, 0, st.axis_index)
        want = tuple(s for d, s in enumerate(ds.shape)
                     if d != st.axis_index)
        got = tuple(s for d, s in enumerate(arr.shape)
                    if d != st.axis_index)
        if want != got:
            raise ValueError(f"feed: frame shape {got} != dataset "
                             f"frame shape {want}")
        if st.eof:
            raise ValueError("feed after eof")
        if int(start) != st.ingested:
            raise ValueError(f"feed at frame {start}, expected "
                             f"{st.ingested} (out of order)")
        k = arr.shape[st.axis_index]
        if st.ingested + k > st.total:
            raise ValueError(
                f"feed of {k} frames at {start} overruns the dataset "
                f"extent {st.total}")
        self._write_slab(ds, st.axis_index, st.ingested, st.ingested + k,
                         arr.astype(ds.dtype, copy=False))
        st.ingested += k
        ds.available_extent = st.ingested
        return st.ingested

    def mark_eof(self) -> None:
        st = self._require_stream()
        if st.ingested != st.total:
            raise ValueError(f"eof at frame {st.ingested}/{st.total} — "
                             f"the stream must cover the dataset extent")
        st.eof = True

    def pump(self) -> int:
        """Execute everything the arrived prefix allows: advance every
        runnable windowed plugin over its new slab, then complete groups
        in order (windows once their cursor covers the full extent,
        barriers via the normal transport path once every streaming
        input is complete).  Steps therefore still complete IN ORDER —
        ``current_step`` keeps meaning "count of fully-completed steps"
        and checkpoints taken mid-stream sit at the first incomplete
        group.  Returns the number of executions performed."""
        st = self._require_stream()
        if self._in_step:
            raise RuntimeError("pump during an open step")
        devices = getattr(getattr(self.transport, "mesh", None), "size", 1)
        progressed = 0
        moved = True
        while moved:
            moved = False
            # 1) windowed plugins run ahead of the step cursor over
            #    whatever new slab their streaming inputs expose
            for g in range(self._step_i, len(self._groups)):
                for j, p in enumerate(self._groups[g]):
                    if st.kind[(g, j)] != "window":
                        continue
                    static_ready = all(
                        self._producer_of.get(id(pd.dataset), -1)
                        < self._step_i
                        for pd in p.in_data
                        if id(pd.dataset) not in st.axes)
                    if not static_ready:
                        continue
                    lo = st.cursors[(g, j)]
                    hi = min((pd.dataset.available_extent or 0)
                             for pd in p.in_data
                             if id(pd.dataset) in st.axes)
                    if hi <= lo:
                        continue
                    if (g, j) not in st.begun:
                        with self.profiler.timer(p.name, "pre", devices):
                            p.pre_process()
                        st.begun.add((g, j))
                    with self.profiler.timer(p.name, "process", devices,
                                             window=[lo, hi]):
                        self._run_window(p, lo, hi)
                    st.cursors[(g, j)] = hi
                    for pd in p.out_data:
                        pd.dataset.available_extent = hi
                    moved = True
                    progressed += 1
            # 2) complete groups in order as they become fully done
            while self._step_i < len(self._groups):
                g = self._step_i
                group = self._groups[g]
                if all(st.kind[(g, j)] == "window"
                       for j in range(len(group))):
                    if not all(st.cursors[(g, j)] >= st.total
                               for j in range(len(group))):
                        break
                    for p in group:
                        with self.profiler.timer(p.name, "post", devices):
                            p.post_process()
                        self._replace(p)
                    self._step_i += 1
                else:
                    ready = all(
                        (pd.dataset.available_extent is None
                         or pd.dataset.available_extent
                         >= pd.dataset.shape[st.axes[id(pd.dataset)]])
                        for p in group for pd in p.in_data
                        if id(pd.dataset) in st.axes)
                    if not ready:
                        break
                    self.step()
                    progressed += 1
                moved = True
        return progressed

    def _run_window(self, p: BasePlugin, lo: int, hi: int) -> None:
        """Host-numpy execution of one windowed plugin over frames
        [lo, hi) of the arrival axis — mirrors InMemoryTransport's frame
        loop exactly (n_frames == 1 per the window classification), so a
        streamed run is bit-identical to the batch run."""
        st = self._stream
        in_slabs = []
        for pd in p.in_data:
            ds = pd.dataset
            if id(ds) in st.axes:
                in_slabs.append(self._read_slab(ds, st.axes[id(ds)],
                                                lo, hi))
            else:
                b = ds.materialise()
                in_slabs.append(b.read_all() if hasattr(b, "read_all")
                                else np.asarray(b))
        in_frames = [np.asarray(pd.pattern.to_frames(slab,
                                                     shape=slab.shape))
                     for pd, slab in zip(p.in_data, in_slabs)]
        nf = in_frames[0].shape[0]
        out_accum: list[list[np.ndarray]] = [[] for _ in p.out_data]
        for start in range(nf):
            blocks = [f[start:start + 1] for f in in_frames]
            res = p.process_frames(blocks)
            if not isinstance(res, (list, tuple)):
                res = [res]
            for i, r in enumerate(res):
                out_accum[i].append(np.asarray(r))
        for pd, pieces in zip(p.out_data, out_accum):
            od = pd.dataset
            oi = st.axes[id(od)]
            oshape = tuple(hi - lo if d == oi else s
                           for d, s in enumerate(od.shape))
            flat = np.concatenate(pieces, axis=0)
            vals = np.asarray(pd.pattern.from_frames(flat, oshape))
            self._write_slab(od, oi, lo, hi,
                             vals.astype(od.dtype, copy=False))

    def preview(self) -> tuple[np.ndarray, int]:
        """Partial result from the arrived prefix: re-run the chain's
        tail (everything from the first barrier on) over the angle
        prefix that has fully traversed the windowed head, on a
        throwaway in-memory transport with freshly instantiated plugins
        — the live runner's state is read, never written.  Returns
        ``(array, watermark)`` where ``watermark`` is the number of
        arrival-axis frames the preview covers.  Raises ValueError while
        nothing has cleared the windowed stages yet."""
        st = self._require_stream()
        res_name = self.result_names()[0]
        barrier_g = None
        for g in range(len(self._groups)):
            if any(st.kind[(g, j)] != "window"
                   for j in range(len(self._groups[g]))):
                barrier_g = g
                break
        if barrier_g is None:
            # fully-windowed chain: the final dataset IS the preview
            final = self._final[res_name]
            cut = final.available_extent or 0
            if cut <= 0:
                raise ValueError("no preview available yet")
            return (self._read_slab(final, st.axes[id(final)], 0, cut),
                    cut)
        cut = None
        for p in self._groups[barrier_g]:
            for pd in p.in_data:
                if id(pd.dataset) in st.axes:
                    e = pd.dataset.available_extent or 0
                    cut = e if cut is None else min(cut, e)
        if not cut:
            raise ValueError("no preview available yet: no frames have "
                             "cleared the windowed stages")
        tail = [p for g in range(barrier_g, len(self._groups))
                for p in self._groups[g]]
        transport = InMemoryTransport()
        new_of: dict[int, DataSet] = {}

        def source(od: DataSet) -> DataSet:
            if id(od) not in st.axes:
                if hasattr(od.backing, "read_all"):   # ChunkedFile
                    return DataSet(od.name, od.shape, od.dtype,
                                   od.axis_labels,
                                   patterns=dict(od.patterns),
                                   metadata=dict(od.metadata),
                                   backing=od.backing.read_all(),
                                   produced_by=od.produced_by)
                return od                  # static input: read-only share
            ai = st.axes[id(od)]
            if (od.available_extent or 0) < cut:
                raise ValueError(
                    f"preview: stream {od.name!r} only at "
                    f"{od.available_extent}/{cut}")
            shape = tuple(cut if d == ai else s
                          for d, s in enumerate(od.shape))
            return DataSet(od.name, shape, od.dtype, od.axis_labels,
                           patterns=dict(od.patterns),
                           metadata=dict(od.metadata),
                           backing=self._read_slab(od, ai, 0, cut),
                           produced_by=od.produced_by)

        for orig in tail:
            fresh = self._entry_of[id(orig)].instantiate()
            ins = []
            for pd in orig.in_data:
                nd = new_of.get(id(pd.dataset))
                if nd is None:
                    nd = new_of[id(pd.dataset)] = source(pd.dataset)
                ins.append(nd)
            fresh.in_data = [PluginData(d) for d in ins]
            fresh.out_data = []
            outs = fresh.setup(ins)
            for ds_out, name in zip(outs, fresh.out_dataset_names):
                ds_out.name = name
                fresh.out_data.append(PluginData(ds_out))
            for pd, opd in zip(fresh.out_data, orig.out_data):
                pd.pattern_name = opd.pattern_name
                pd.n_frames = opd.n_frames
                if pd.pattern_name not in pd.dataset.patterns and \
                        pd.pattern_name in ins[0].patterns and \
                        pd.dataset.shape == ins[0].shape:
                    pd.dataset.patterns[pd.pattern_name] = \
                        ins[0].patterns[pd.pattern_name]
                transport.allocate(
                    pd.dataset, pd.dataset.patterns.get(pd.pattern_name),
                    None)
                new_of[id(opd.dataset)] = pd.dataset
            fresh.pre_process()
            transport.run_plugin(fresh)
            fresh.post_process()
        orig_final = self._final[res_name]
        nd = new_of.get(id(orig_final))
        if nd is None:
            raise RuntimeError(f"preview did not produce {res_name!r}")
        return np.asarray(nd.materialise()), cut

    def stream_state(self) -> dict[str, Any] | None:
        """Checkpointable stream snapshot (None when not streaming).
        Window cursors are intentionally NOT persisted: a restore resets
        them and recomputes the windowed head from the restored prefix,
        which keeps the checkpoint to exactly the datasets batch resume
        already captures."""
        if self._stream is None:
            return None
        st = self._stream
        return {"dataset": st.dataset.name, "axis": st.axis_label,
                "ingested": st.ingested, "eof": st.eof,
                "total": st.total}

    def restore_stream_state(self, state: dict[str, Any]) -> None:
        """Re-arm streaming from a checkpoint's ``stream`` block.  Call
        after the checkpointed datasets have been loaded — the ingest
        watermark is restored and the next :meth:`pump` recomputes the
        windowed head over the restored prefix."""
        self.enable_streaming(dataset=state.get("dataset"),
                              axis=state.get("axis"))
        st = self._stream
        st.ingested = int(state.get("ingested", 0))
        st.eof = bool(state.get("eof", False))
        st.dataset.available_extent = st.ingested
        # groups already completed before the checkpoint hold finished
        # (checkpoint-restored) data — mark their windows complete so
        # downstream consumers see the full extent
        for (g, j) in list(st.cursors):
            if g < self._step_i:
                st.cursors[(g, j)] = st.total
                for pd in self._groups[g][j].out_data:
                    pd.dataset.available_extent = st.total

    # ------------------------------------------------------------------
    def _split(self):
        loaders, procs, savers = [], [], []
        #: id(plugin) -> its ProcessList entry, so preview() can
        #: re-instantiate a fresh copy of a tail plugin
        self._entry_of = {}
        for entry in self.process_list:
            plugin = entry.instantiate()
            self._entry_of[id(plugin)] = entry
            if isinstance(plugin, BaseLoader):
                loaders.append(plugin)
            elif isinstance(plugin, BaseSaver):
                savers.append(plugin)
            else:
                procs.append(plugin)
        return loaders, procs, savers

    def _setup_phase(self, loaders, processors, savers):
        # Loaders first (lazy — they create dataset descriptions).
        for ld in loaders:
            with self.profiler.timer(ld.name, "setup"):
                for ds in ld.load():
                    if not ld.out_dataset_names:
                        ld.out_dataset_names = []
                    self.datasets[ds.name] = ds
                    self.lineage.append(ds)
        # Savers are plugged in directly after loaders (paper §III.F.2)
        # and retain their link until finalise.
        # Processing plugins: attach PluginData, call setup, register outs.
        self._planned: list[tuple[BasePlugin, list[DataSet]]] = []
        sym: dict[str, DataSet] = dict(self.datasets)
        for i, p in enumerate(processors):
            ins = [sym[n] for n in p.in_dataset_names]
            p.in_data = [PluginData(d) for d in ins]
            p.out_data = []          # filled after setup describes them
            with self.profiler.timer(p.name, "setup"):
                outs = p.setup(ins)
            if len(outs) != len(p.out_dataset_names):
                raise ValueError(
                    f"plugin {p.name}: setup returned {len(outs)} datasets, "
                    f"process list names {p.out_dataset_names}")
            for ds, name in zip(outs, p.out_dataset_names):
                ds.name = name
                ds.produced_by = f"p{i + 1}.{p.name}"
                p.out_data.append(PluginData(ds))
            # propagate pattern/frames choice made in setup to out views
            for pd in p.out_data:
                pd.pattern_name = (p.out_pattern_name or pd.pattern_name
                                   or p.in_data[0].pattern_name)
                pd.n_frames = p.in_data[0].n_frames
                if pd.pattern_name not in pd.dataset.patterns and \
                        pd.pattern_name in ins[0].patterns and \
                        pd.dataset.shape == ins[0].shape:
                    pd.dataset.patterns[pd.pattern_name] = \
                        ins[0].patterns[pd.pattern_name]
            # transport attaches backing (file/None) using now/next patterns
            nxt = processors[i + 1] if i + 1 < len(processors) else None
            for pd in p.out_data:
                now_pat = pd.dataset.patterns.get(pd.pattern_name)
                next_pat = None
                if nxt is not None and pd.dataset.name in nxt.in_dataset_names:
                    # the next plugin's requested pattern, if resolvable
                    cand = nxt.__class__.__dict__.get("pattern_name")
                    if cand and cand in pd.dataset.patterns:
                        next_pat = pd.dataset.patterns[cand]
                if now_pat is not None:
                    self.transport.allocate(pd.dataset, now_pat, next_pat)
                self.lineage.append(pd.dataset)
            self._planned.append((p, outs))
            for ds in outs:
                sym[ds.name] = ds
        #: final version of every dataset name (what savers will see)
        self._final = dict(sym)

    def _replace(self, p: BasePlugin):
        """out_dataset replaces in_dataset of the same name (Fig 6 (i))."""
        for pd in p.out_data:
            self.datasets[pd.dataset.name] = pd.dataset
        consumed = {pd.dataset.name for pd in p.in_data}
        produced = {pd.dataset.name for pd in p.out_data}
        # close in_datasets that were replaced (paper removes them)
        for name in consumed & produced:
            pass  # the registry overwrite above is the replacement

    def _fusion_groups(self, processors):
        """Group consecutive linear 1-in/1-out jax-traceable plugins."""
        groups: list[list[BasePlugin]] = []
        cur: list[BasePlugin] = []
        for p in processors:
            linear = (len(p.in_dataset_names) == 1
                      and len(p.out_dataset_names) == 1
                      and getattr(p, "fusable", True))
            chains = bool(cur) and \
                cur[-1].out_dataset_names[0] == p.in_dataset_names[0] and \
                cur[-1].driver == p.driver
            if linear and (not cur or chains):
                cur.append(p)
            else:
                if cur:
                    groups.append(cur)
                cur = [p] if linear else []
                if not linear:
                    groups.append([p])
        if cur:
            groups.append(cur)
        return groups

    # ------------------------------------------------------------------
    def _finalise(self, savers):
        for sv in savers:
            for name in sv.in_dataset_names:
                if name in self.datasets:
                    with self.profiler.timer(sv.name, "io"):
                        sv.save(self.datasets[name])
        if self.output_dir:
            os.makedirs(self.output_dir, exist_ok=True)
            manifest = {
                "created": time.strftime("%Y-%m-%dT%H:%M:%S"),
                "datasets": [
                    {"name": d.name, "shape": list(d.shape),
                     "dtype": str(d.dtype), "axis_labels": list(d.axis_labels),
                     "produced_by": d.produced_by,
                     "patterns": sorted(d.patterns),
                     "file": getattr(getattr(d, "backing", None), "path", None)}
                    for d in self.lineage],
            }
            with open(os.path.join(self.output_dir, "savu_manifest.nxs.json"),
                      "w") as fh:
                json.dump(manifest, fh, indent=2)
        self.transport.close()


# convenience ----------------------------------------------------------
def run_process_list(process_list: ProcessList,
                     data: dict[str, Any] | None = None,
                     transport: Transport | None = None, **kw
                     ) -> dict[str, DataSet]:
    """One-shot helper used by examples/tests: ``data`` pre-populates
    loader-created datasets (name -> host array) before the chain steps,
    so a process list whose loader only *describes* a dataset can be fed
    inline arrays."""
    runner = PluginRunner(process_list, transport, **kw)
    runner.prepare()
    for name, arr in (data or {}).items():
        ds = runner.datasets.get(name)
        if ds is None or ds.produced_by:
            continue                      # only loader-created datasets
        if hasattr(ds.backing, "write_all"):
            ds.backing.write_all(np.asarray(arr))
        else:
            ds.backing = arr
    while runner.step():
        pass
    runner.finalise()
    return runner.datasets
