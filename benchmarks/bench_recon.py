"""FBP hot-spot benchmark: backprojection kernel (interpret mode) vs
pure-jnp reference, plus the fused correction kernel, with derived
throughput.  On real TPU the Pallas path replaces the gather-bound ref
with MXU matmuls; interpret-mode wall time here only validates cost
ratios, not absolute speed."""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.backproject.ops import backproject
from repro.kernels.backproject.ref import backproject_ref
from repro.kernels.correction.ops import correct


def _time(fn, *args, reps=3):
    fn(*args).block_until_ready()         # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.tree.map(lambda x: x.block_until_ready(), out)
    return (time.perf_counter() - t0) / reps


def run(report):
    A, D, N = 64, 128, 128
    rng = np.random.default_rng(0)
    sino = jnp.asarray(rng.normal(size=(A, D)).astype(np.float32))
    angles = jnp.linspace(0, np.pi, A, endpoint=False)

    t_ref = _time(lambda s: backproject_ref(s, angles, N), sino)
    flops = 2.0 * A * N * N * D            # hat-matmul formulation
    report("fbp_ref_jnp", t_ref * 1e6,
           f"{flops / t_ref / 1e9:.1f} GFLOP/s-equiv (gather form)")

    t_pal = _time(lambda s: backproject(s, angles, N, use_pallas=True,
                                        interpret=True), sino)
    report("fbp_pallas_interpret", t_pal * 1e6,
           "interpret-mode correctness path (TPU target: MXU matmul)")

    raw = jnp.asarray(rng.integers(100, 40000, size=(16, 64, 512))
                      .astype(np.uint16))
    dark = jnp.asarray(np.full((64, 512), 96, np.uint16))
    flat = jnp.asarray(np.full((64, 512), 40000, np.uint16))
    t_corr = _time(lambda r: correct(r, dark, flat, use_pallas=False), raw)
    px = raw.size
    report("correction_fused", t_corr * 1e6,
           f"{px / t_corr / 1e6:.0f} Mpixel/s (xla ref)")
