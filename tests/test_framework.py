"""Framework behaviour: transports agree, fusion agrees, replacement
semantics, multi-dataset chains (Fig 10), profiler."""
import numpy as np
import pytest

import jax

from repro.core import (BaseLoader, BasePlugin, BaseSaver, ChunkedFile,
                        ChunkedFileTransport, DataSet, InMemoryTransport,
                        LambdaFilter, PluginRunner, ProcessList,
                        ShardedTransport)
pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st


class ArrayLoader(BaseLoader):
    name = "array_loader"

    def __init__(self, array=None, labels=("theta", "y", "x"), **kw):
        super().__init__(**kw)
        self.array = array
        self.labels = labels

    def load(self):
        d = DataSet(self.out_dataset_names[0], self.array.shape,
                    self.array.dtype, self.labels, backing=self.array)
        d.add_pattern("PROJECTION", core=self.labels[1:],
                      slice_=self.labels[:1])
        d.add_pattern("SINOGRAM",
                      core=(self.labels[0], self.labels[2]),
                      slice_=(self.labels[1],))
        return [d]


class CaptureSaver(BaseSaver):
    name = "capture_saver"
    captured = {}

    def save(self, ds):
        b = ds.backing
        CaptureSaver.captured[ds.name] = (
            b.read_all() if isinstance(b, ChunkedFile) else np.asarray(b))


def _chain(a, frames=1):
    pl = ProcessList()
    pl.add(ArrayLoader, params={"array": a}, out_datasets=("tomo",))
    pl.add(LambdaFilter,
           params={"fn": lambda b: b * 2.0, "pattern": "PROJECTION",
                   "frames": frames},
           in_datasets=("tomo",), out_datasets=("tomo",))
    pl.add(LambdaFilter,
           params={"fn": lambda b: b + 1.0, "pattern": "SINOGRAM",
                   "frames": frames},
           in_datasets=("tomo",), out_datasets=("tomo",))
    pl.add(CaptureSaver, in_datasets=("tomo",))
    return pl


@pytest.fixture
def data(rng):
    return rng.normal(size=(8, 6, 4)).astype(np.float32)


def test_transports_agree(data):
    """in-memory, chunked-file and sharded transports produce identical
    results for the same chain (the paper's serial-vs-MPI equivalence)."""
    expect = data * 2 + 1
    for transport in (InMemoryTransport(), ChunkedFileTransport()):
        CaptureSaver.captured = {}
        PluginRunner(_chain(data), transport).run()
        np.testing.assert_allclose(CaptureSaver.captured["tomo"], expect,
                                   rtol=1e-6)
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    CaptureSaver.captured = {}
    PluginRunner(_chain(data), ShardedTransport(mesh)).run()
    np.testing.assert_allclose(CaptureSaver.captured["tomo"], expect,
                               rtol=1e-5)


def test_fusion_matches_unfused(data):
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    CaptureSaver.captured = {}
    PluginRunner(_chain(data), ShardedTransport(mesh), fuse=True).run()
    fused = CaptureSaver.captured["tomo"]
    np.testing.assert_allclose(fused, data * 2 + 1, rtol=1e-5)


def test_multi_frame_processing(data):
    CaptureSaver.captured = {}
    PluginRunner(_chain(data, frames=2), InMemoryTransport()).run()
    np.testing.assert_allclose(CaptureSaver.captured["tomo"],
                               data * 2 + 1, rtol=1e-6)


def test_dataset_replacement_semantics(data):
    """An out_dataset with the same name replaces the in_dataset; a new
    name creates a parallel dataset (paper §III.B)."""
    pl = ProcessList()
    pl.add(ArrayLoader, params={"array": data}, out_datasets=("tomo",))
    pl.add(LambdaFilter, params={"fn": lambda b: b * 2.0},
           in_datasets=("tomo",), out_datasets=("doubled",))
    pl.add(LambdaFilter, params={"fn": lambda b: b + 5.0},
           in_datasets=("tomo",), out_datasets=("tomo",))
    pl.add(CaptureSaver, in_datasets=("doubled",))
    pl.add(CaptureSaver, in_datasets=("tomo",))
    CaptureSaver.captured = {}
    runner = PluginRunner(pl, InMemoryTransport())
    out = runner.run()
    # 'doubled' was computed from the ORIGINAL tomo
    np.testing.assert_allclose(CaptureSaver.captured["doubled"], data * 2)
    np.testing.assert_allclose(CaptureSaver.captured["tomo"], data + 5)
    assert set(out) == {"tomo", "doubled"}


def test_multi_loader_multimodal_chain(rng):
    """Fig 10: multiple loaders, a 2-in plugin combining datasets."""
    absorb = rng.normal(size=(4, 4, 4)).astype(np.float32)
    fluo = rng.normal(size=(4, 4, 4)).astype(np.float32)

    class TwoIn(BasePlugin):
        name = "combine"
        n_in_datasets = 2
        n_out_datasets = 1

        def setup(self, ins):
            dout = ins[1].like(self.out_dataset_names[0])
            self.chunk_frames(self.default_pattern(ins[0]))
            return [dout]

        def process_frames(self, frames):
            a, f = frames
            return f / (1.0 + np.abs(a))

    pl = ProcessList()
    pl.add(ArrayLoader, params={"array": absorb}, out_datasets=("absorb",))
    pl.add(ArrayLoader, params={"array": fluo}, out_datasets=("fluo",))
    pl.add(TwoIn, in_datasets=("absorb", "fluo"),
           out_datasets=("corrected",))
    pl.add(CaptureSaver, in_datasets=("corrected",))
    CaptureSaver.captured = {}
    PluginRunner(pl, InMemoryTransport()).run()
    np.testing.assert_allclose(CaptureSaver.captured["corrected"],
                               fluo / (1 + np.abs(absorb)), rtol=1e-6)


def test_profiler_records_all_plugins(data):
    runner = PluginRunner(_chain(data), InMemoryTransport())
    runner.run()
    totals = runner.profiler.totals()
    assert "lambda_filter" in totals
    report = runner.profiler.report()
    assert "profile" in report and "#" in report


def test_manifest_written(tmp_path, data):
    runner = PluginRunner(_chain(data), InMemoryTransport(),
                          output_dir=str(tmp_path))
    runner.run()
    import json
    man = json.load(open(tmp_path / "savu_manifest.nxs.json"))
    names = [d["name"] for d in man["datasets"]]
    assert names.count("tomo") >= 2       # lineage keeps intermediates


@given(shape=st.tuples(st.integers(2, 9), st.integers(2, 9),
                       st.integers(2, 9)),
       chunks=st.tuples(st.integers(1, 4), st.integers(1, 4),
                        st.integers(1, 4)))
@settings(max_examples=20, deadline=None)
def test_chunked_file_region_io(tmp_path_factory, shape, chunks):
    """Property: ChunkedFile read(write(x)) == x for random regions."""
    import tempfile
    rng = np.random.default_rng(1)
    d = tempfile.mkdtemp()
    cf = ChunkedFile(f"{d}/t.dat", shape, np.float32, chunks,
                     cache_bytes=1024)
    ref = rng.normal(size=shape).astype(np.float32)
    cf.write_all(ref)
    np.testing.assert_array_equal(cf.read_all(), ref)
    # random sub-region
    lo = [rng.integers(0, s) for s in shape]
    hi = [int(rng.integers(l + 1, s + 1)) for l, s in zip(lo, shape)]
    region = tuple(slice(int(l), int(h)) for l, h in zip(lo, hi))
    np.testing.assert_array_equal(cf.read(region), ref[region])
    # partial write
    val = rng.normal(size=tuple(h - l for l, h in zip(lo, hi))
                     ).astype(np.float32)
    cf.write(region, val)
    cf.flush()
    ref[region] = val
    np.testing.assert_array_equal(cf.read_all(), ref)
