"""End-to-end training example: train a small decoder LM for a few
hundred steps with checkpointing + restart (kill it mid-run and rerun —
it resumes).  Thin wrapper over the production driver.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--arch", "granite-8b", "--smoke",
                "--steps", "200", "--batch", "8", "--seq", "128",
                "--ckpt-dir", "out/train_lm_ckpt"] + sys.argv[1:]
    main()
