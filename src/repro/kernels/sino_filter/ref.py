"""Pure-jnp oracle: ramp (Ram-Lak / Shepp-Logan / cosine) sinogram
filtering for FBP, via rFFT along the detector axis."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def make_filter(n_det: int, kind: str = "ramlak",
                pad_to: int | None = None) -> np.ndarray:
    """Frequency response |f| × window, length n_fft//2+1 (rfft bins)."""
    n_fft = pad_to or _next_pow2(2 * n_det)
    freqs = np.fft.rfftfreq(n_fft)              # [0, 0.5] cycles/sample
    ramp = freqs                                # |ω| of the FBP integral;
    # pairs with the π/n_angles backprojection scale (ops.backproject)
    if kind == "ramlak":
        win = np.ones_like(ramp)
    elif kind == "shepp":
        win = np.sinc(freqs)                    # sinc(f/ (2 fN)) variant
    elif kind == "cosine":
        win = np.cos(np.pi * freqs)
    elif kind == "hann":
        win = 0.5 * (1 + np.cos(2 * np.pi * freqs))
    else:
        raise ValueError(f"unknown filter kind {kind!r}")
    return (ramp * win).astype(np.float32)


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def filter_sino_ref(sino: jnp.ndarray, filt: jnp.ndarray) -> jnp.ndarray:
    """(..., n_det) real sinogram rows × precomputed rfft filter."""
    n_det = sino.shape[-1]
    n_fft = 2 * (filt.shape[-1] - 1)
    spec = jnp.fft.rfft(sino, n=n_fft, axis=-1)
    spec = spec * filt.astype(spec.real.dtype)
    out = jnp.fft.irfft(spec, n=n_fft, axis=-1)
    return out[..., :n_det].astype(sino.dtype)
