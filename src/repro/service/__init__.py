# The service layer — from processing *framework* to facility *service*
# (the step Nanosurveyor/Daisy make explicit): a multi-tenant scheduler
# that runs many process lists concurrently over shared workers, with a
# process-level compiled-plugin cache and checkpoint/resume.
from .compile_cache import CompileCache
from .checkpoint import CheckpointError, CheckpointStore
from .job import Job, JobState, chain_signature
from .queue import JobQueue, QueueFull
from .scheduler import PipelineScheduler

__all__ = [
    "Job", "JobState", "chain_signature", "JobQueue", "QueueFull",
    "CompileCache", "CheckpointError", "CheckpointStore",
    "PipelineScheduler",
]
