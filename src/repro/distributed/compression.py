"""Gradient compression for cross-pod data parallelism.

int8 block-quantised all-reduce with error feedback: the inter-pod DCI
link is ~10× slower than intra-pod ICI, so the pod-boundary gradient
reduction is the place compression pays.  The intra-pod reduction stays
full-precision (XLA's native all-reduce); only the ``pod`` axis uses
the quantised path.

``compressed_psum`` is written with shard_map so it lowers to a real
collective on the named axis; error feedback keeps the quantisation
noise unbiased over steps (residual carried in fp32).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

BLOCK = 256


def quantise_int8(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-block symmetric int8.  x flat fp32 -> (q int8, scales fp32)."""
    n = x.size
    pad = (-n) % BLOCK
    xf = jnp.pad(x.reshape(-1), (0, pad)).reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(xf), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantise_int8(q: jnp.ndarray, scale: jnp.ndarray, n: int,
                    shape: tuple[int, ...]) -> jnp.ndarray:
    x = (q.astype(jnp.float32) * scale).reshape(-1)[:n]
    return x.reshape(shape)


def quantise_tree(grads: Any, residual: Any | None = None
                  ) -> tuple[Any, Any, Any]:
    """Quantise every leaf with error feedback.

    Returns (quantised leaves (q, scale), dequantised grads, new
    residual).  Callers all-reduce the dequantised grads (simulating the
    int8 wire format; on real DCI the int8 payload is what moves)."""
    if residual is None:
        residual = jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q, s = quantise_int8(gf)
        deq = dequantise_int8(q, s, gf.size, gf.shape)
        return (q, s), deq, gf - deq

    trip = jax.tree.map(one, grads, residual,
                        is_leaf=lambda x: hasattr(x, "shape"))
    qs = jax.tree.map(lambda t: t[0], trip,
                      is_leaf=lambda t: isinstance(t, tuple) and
                      len(t) == 3)
    deq = jax.tree.map(lambda t: t[1], trip,
                       is_leaf=lambda t: isinstance(t, tuple) and
                       len(t) == 3)
    res = jax.tree.map(lambda t: t[2], trip,
                       is_leaf=lambda t: isinstance(t, tuple) and
                       len(t) == 3)
    return qs, deq, res


def compressed_psum(x: jnp.ndarray, mesh: Mesh, axis: str = "pod"
                    ) -> jnp.ndarray:
    """int8-quantise → psum over ``axis`` → dequantise, as a shard_map
    collective.  Payload on the wire is (int8 q, fp32 scales) ≈ 4×
    smaller than fp32."""
    if axis not in mesh.axis_names:
        return x
    spec = P()            # replicated view; reduction over `axis` only

    def f(xs):
        n = xs.size
        pad = (-n) % BLOCK
        blocks = jnp.pad(xs.astype(jnp.float32).reshape(-1),
                         (0, pad)).reshape(-1, BLOCK)
        # agree on a shared per-block scale: max over pod participants
        # (tiny fp32 pmax, n/BLOCK values on the wire)
        local_max = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
        gmax = jax.lax.pmax(local_max, axis)
        scale = jnp.maximum(gmax / 127.0, 1e-12)
        q = jnp.clip(jnp.round(blocks / scale), -127, 127
                     ).astype(jnp.int8)
        # int8 payload is what crosses the DCI; psum in int32 accumulators
        qsum = jax.lax.psum(q.astype(jnp.int32), axis)
        out = (qsum.astype(jnp.float32) * scale).reshape(-1)[:n]
        return out.reshape(xs.shape).astype(x.dtype)

    return shard_map(f, mesh=mesh, in_specs=(spec,), out_specs=spec,
                     check_rep=False)(x)
