"""Checkpoint-subsystem benchmark: bytes written and wall overhead per
checkpoint — the incremental chunk-addressed v2 store against the dense
v1 ``.npy`` path — plus a kill/resume equivalence check on both the
dense and the chunked-file transports.

The chain keeps a loader volume live until its LAST plugin (a branching
quality-check consumes raw + processed), so the dense path must re-dump
it at every checkpoint while v2 writes each dataset version exactly once
(ChunkedFile backings are flushed + hard-linked: steady-state bytes per
checkpoint are the dirty-chunk bytes, ~0 for write-once datasets).

Standalone:  PYTHONPATH=src python benchmarks/bench_checkpoint.py
Smoke (CI):  PYTHONPATH=src python benchmarks/bench_checkpoint.py --smoke
Harness:     python -m benchmarks.run   (row prefix ``checkpoint_``)
"""
from __future__ import annotations

import argparse
import shutil
import tempfile

import numpy as np

from repro.core import (BaseFilter, BaseLoader, BasePlugin, BaseSaver,
                        ChunkedFileTransport, DataSet, InMemoryTransport,
                        PluginRunner, ProcessList)
from repro.service import CheckpointStore

SHAPE = (32, 48, 48)
N_FILTERS = 4


class VolumeLoader(BaseLoader):
    name = "volume_loader"
    parameters = {"shape": None, "seed": 0}
    data_params = ("seed",)

    def load(self):
        shape = tuple(self.params["shape"])
        rng = np.random.default_rng(self.params["seed"])
        a = rng.normal(size=shape).astype(np.float32)
        d = DataSet(self.out_dataset_names[0], a.shape, a.dtype,
                    ("z", "y", "x"), backing=a)
        d.add_pattern("SLAB", core=("y", "x"), slice_=("z",))
        return [d]


class Smooth(BaseFilter):
    name = "smooth"
    parameters = {"add": 0.0}

    def process_frames(self, frames):
        return frames[0] * 0.99 + self.params["add"]


class QualityCheck(BasePlugin):
    """Branching consumer: needs the RAW volume back at the end of the
    chain — the case that keeps a dataset required-live across every
    intermediate checkpoint."""
    name = "quality_check"
    n_in_datasets = 2

    def setup(self, in_datasets):
        dout = in_datasets[0].like(self.out_dataset_names[0])
        self.chunk_frames(self.default_pattern(in_datasets[0]))
        return [dout]

    def process_frames(self, frames):
        return frames[0] - 0.5 * frames[1]


class NullSaver(BaseSaver):
    name = "null_saver"

    def save(self, ds):
        ds.metadata["saved"] = True


def _chain(shape, n_filters=N_FILTERS, seed=0) -> ProcessList:
    pl = ProcessList()
    pl.add(VolumeLoader, params={"shape": list(shape), "seed": seed},
           out_datasets=("raw",))
    pl.add(Smooth, params={"add": 1.0},
           in_datasets=("raw",), out_datasets=("work",))
    for i in range(n_filters - 1):
        pl.add(Smooth, params={"add": float(i)},
               in_datasets=("work",), out_datasets=("work",))
    pl.add(QualityCheck, in_datasets=("work", "raw"),
           out_datasets=("out",))
    pl.add(NullSaver, in_datasets=("out",))
    return pl


def _ckpt_run(shape, n_filters, transport_factory, store) -> dict:
    """Run the chain, checkpointing after every step; per-step stats."""
    runner = PluginRunner(_chain(shape, n_filters), transport_factory())
    runner.prepare()
    per_step = []
    while runner.step():
        per_step.append(store.save("bench", runner))
    runner.finalise()
    store.clear("bench")
    return {
        "bytes": [s["bytes_written"] for s in per_step],
        "wall": sum(s["wall"] for s in per_step),
        "steady": (np.mean([s["bytes_written"] for s in per_step[1:]])
                   if len(per_step) > 1 else per_step[0]["bytes_written"]),
    }


def _resume_run(shape, n_filters, transport_factory, store,
                kill_after: int) -> np.ndarray:
    """Interrupt after ``kill_after`` steps, resume fresh, return out."""
    r = PluginRunner(_chain(shape, n_filters), transport_factory())
    r.prepare()
    for _ in range(kill_after):
        r.step()
        store.save("bench-resume", r)
    # "kill": drop the runner, resume a fresh one from the store
    r2 = PluginRunner(_chain(shape, n_filters), transport_factory())
    resumed = store.restore("bench-resume", r2)
    assert resumed == kill_after, (resumed, kill_after)
    while r2.step():
        pass
    r2.finalise()
    store.clear("bench-resume")
    return np.asarray(r2.transport.read(r2.datasets["out"]))


def run(report, shape=SHAPE, n_filters=N_FILTERS) -> None:
    dense_volume = int(np.prod(shape)) * 4
    tmp = tempfile.mkdtemp(prefix="bench_ckpt_")
    tr_dirs = iter(range(1000))

    def chunked_factory():
        return ChunkedFileTransport(
            directory=f"{tmp}/tr_{next(tr_dirs)}")

    transports = [
        ("dense", InMemoryTransport),
        ("chunked", chunked_factory),
    ]
    for tname, factory in transports:
        v1 = _ckpt_run(shape, n_filters, factory,
                       CheckpointStore(f"{tmp}/v1_{tname}", format="npy"))
        v2 = _ckpt_run(shape, n_filters, factory,
                       CheckpointStore(f"{tmp}/v2_{tname}"))
        ratio = v1["steady"] / max(1.0, v2["steady"])
        report(f"checkpoint_{tname}_v1_dense",
               v1["wall"] / len(v1["bytes"]) * 1e6,
               f"{v1['steady'] / 1e3:.0f} kB/ckpt steady "
               f"(volume={dense_volume / 1e3:.0f} kB)")
        report(f"checkpoint_{tname}_v2_incremental",
               v2["wall"] / len(v2["bytes"]) * 1e6,
               f"{v2['steady'] / 1e3:.0f} kB/ckpt steady "
               f"({ratio:.0f}x less than v1)")
        assert v2["steady"] < v1["steady"], \
            f"{tname}: incremental checkpoints wrote {v2['steady']} B " \
            f">= dense {v1['steady']} B per steady-state checkpoint"

        # kill/resume equivalence: interrupted == uninterrupted, bitwise
        rref = PluginRunner(_chain(shape, n_filters), factory())
        rref.run()
        want = np.asarray(rref.transport.read(rref.datasets["out"]))
        got = _resume_run(shape, n_filters, factory,
                          CheckpointStore(f"{tmp}/resume_{tname}"),
                          kill_after=2)
        np.testing.assert_array_equal(got, want)
        report(f"checkpoint_{tname}_resume_ok", 0.0,
               "interrupted == uninterrupted (bit-identical)")
    shutil.rmtree(tmp, ignore_errors=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI")
    ap.add_argument("--n-filters", type=int, default=N_FILTERS)
    args = ap.parse_args()
    shape = (8, 16, 16) if args.smoke else SHAPE
    print("name,us_per_call,derived")

    def report(name, us, derived=""):
        print(f"{name},{us:.1f},{derived}", flush=True)

    run(report, shape=shape, n_filters=args.n_filters)


if __name__ == "__main__":
    main()
