"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks 7:1 (arXiv:2405.04517).

48L d_model=2048 4H d_ff=0 vocab=50304.  slstm_every=8 gives the
released 7:1 mLSTM:sLSTM ratio (6 sLSTM blocks).  Sub-quadratic:
eligible for the long_500k cell.
"""
import jax.numpy as jnp
from ..models.common import ModelConfig

ARCH_ID = "xlstm-1.3b"

FULL = ModelConfig(
    arch_id=ARCH_ID, family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304, slstm_every=8, dtype=jnp.bfloat16)

SMOKE = ModelConfig(
    arch_id=ARCH_ID + "-smoke", family="ssm",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=257, slstm_every=4,
    dtype=jnp.float32, remat=False)
