"""Reproduces §IV.A: the chunking optimiser vs a pattern-oblivious
layout, on the chunk-file transport with the paper's projection-write →
sinogram-read regime.  Reports chunk I/O counts, cache hits and wall
time for both layouts."""
from __future__ import annotations

import tempfile
import time

import numpy as np

from repro.core import (ChunkedFile, Pattern, naive_chunks,
                        optimise_chunks)

PROJ = Pattern("PROJECTION", core_dims=(1, 2), slice_dims=(0,))
SINO = Pattern("SINOGRAM", core_dims=(0, 2), slice_dims=(1,))


def _roundtrip(shape, chunks, cache_bytes, m=8):
    d = tempfile.mkdtemp()
    cf = ChunkedFile(f"{d}/bench.dat", shape, np.float32, chunks,
                     cache_bytes)
    data = np.random.default_rng(0).normal(size=shape).astype(np.float32)
    t0 = time.perf_counter()
    # write as projections (m frames at a time)
    for idx in PROJ.frame_slices(shape, m):
        cf.write(idx, data[idx])
    cf.flush()
    # read back as sinograms
    for idx in SINO.frame_slices(shape, m):
        cf.read(idx)
    wall = time.perf_counter() - t0
    return cf.stats, wall


def run(report):
    shape = (128, 96, 96)
    cache = 256_000
    copt = optimise_chunks(shape, PROJ, SINO, itemsize=4, frames=8,
                           cache_bytes=cache)
    cnv = naive_chunks(shape, 4, cache)
    s_opt, w_opt = _roundtrip(shape, copt, cache)
    s_nv, w_nv = _roundtrip(shape, cnv, cache)
    io_opt = s_opt.chunk_reads + s_opt.chunk_writes
    io_nv = s_nv.chunk_reads + s_nv.chunk_writes
    report("chunking_optimised", w_opt * 1e6,
           f"chunks={copt} io_ops={io_opt} hits={s_opt.cache_hits}")
    report("chunking_naive", w_nv * 1e6,
           f"chunks={cnv} io_ops={io_nv} hits={s_nv.cache_hits}")
    report("chunking_io_reduction", 0.0,
           f"{io_nv / max(1, io_opt):.2f}x fewer chunk I/O ops")
