"""Wire format for process lists — JSON specs a remote client can POST.

The paper's facility model ("over 3000 scientific users per year")
implies users who submit process lists to a service they do not run.
That requires a *wire format*: a JSON document that names plugins by
their registered wire name (``BasePlugin.name``) rather than by python
class, carries only JSON-serialisable parameters, and is validated
loudly before anything executes.  Spec v1:

.. code-block:: json

    {"version": 1,
     "plugins": [
       {"plugin": "synthetic_tomo_loader",
        "params": {"n_det": 48, "seed": 3},
        "out_datasets": ["tomo"]},
       {"plugin": "fbp_recon",
        "in_datasets": ["tomo"], "out_datasets": ["recon"]},
       {"plugin": "hdf5_saver", "in_datasets": ["recon"]}]}

``from_spec`` resolves each entry against the plugin registry and
raises :class:`WireError` — naming the offender and the valid
alternatives — on unknown plugins, unknown parameters, or malformed
structure; ``to_spec`` is the exact inverse for registry plugins with
JSON-able params.  Structural chain errors (missing loader/saver,
unwired datasets) are still caught by ``ProcessList.check()``, which
the server runs at submit time.  See ``docs/plugin-spec.md``.
"""
from __future__ import annotations

import inspect
from typing import Any, Iterable, Type

from ..core.plugin import BasePlugin, _is_jsonable
from ..core.process_list import PluginEntry, ProcessList

WIRE_VERSION = 1
#: spec v2 = v1 plus the top-level ``"streaming": true`` flag (the
#: loader dataset is fed frame-by-frame via POST /jobs/{id}/frames
#: instead of being complete at step 0 — docs/streaming.md)
WIRE_VERSION_STREAMING = 2
#: spec v3 = the ``POST /workflows`` envelope: a DAG of NODES, each
#: node carrying a v1/v2 process-list spec plus ``"after"`` edges and
#: upstream-result references (docs/workflows.md).  Parsed by
#: ``repro.service.workflow`` — individual process-list specs stay
#: v1/v2, which is why v3 is not in ``_ACCEPTED_VERSIONS`` here.
WIRE_VERSION_WORKFLOW = 3
_ACCEPTED_VERSIONS = (WIRE_VERSION, WIRE_VERSION_STREAMING)

#: wire name -> plugin class.  Seeded with the tomography chain below;
#: extend with :func:`register_plugin`.
_REGISTRY: dict[str, Type[BasePlugin]] = {}


class WireError(ValueError):
    """A process-list spec cannot be (de)serialised: unknown plugin,
    unknown/non-JSON parameter, or malformed document structure."""


def register_plugin(cls: Type[BasePlugin], name: str | None = None
                    ) -> Type[BasePlugin]:
    """Add a plugin class to the wire registry (usable as a decorator).

    Args:
        cls: the plugin class to expose over the wire.
        name: wire name; defaults to ``cls.name``.

    Returns:
        ``cls`` unchanged.

    Raises:
        WireError: if the name is already registered to a DIFFERENT
            class — silent re-pointing would change what existing specs
            execute.
    """
    wire_name = name or cls.name
    existing = _REGISTRY.get(wire_name)
    if existing is not None and existing is not cls:
        raise WireError(
            f"wire name {wire_name!r} already registered to "
            f"{existing.__module__}.{existing.__qualname__}")
    _REGISTRY[wire_name] = cls
    return cls


def registered_plugins() -> dict[str, Type[BasePlugin]]:
    """A copy of the wire registry (name -> class)."""
    return dict(_REGISTRY)


def registry_spec() -> dict[str, Any]:
    """JSON-able description of every registered plugin (served at
    ``GET /plugins``): per plugin the declared parameters with defaults,
    ``data_param`` flags, and dataset arity (``BasePlugin.param_spec``)."""
    return {name: cls.param_spec() for name, cls in sorted(_REGISTRY.items())}


# ----------------------------------------------------------------------
def _valid_params(cls: Type[BasePlugin]) -> set[str]:
    """Parameter names a spec may set: the declared ``parameters`` dict
    plus explicit constructor keywords (mirrors ProcessList.check)."""
    sig = inspect.signature(cls.__init__)
    ctor = {n for n, p in sig.parameters.items()
            if n != "self" and p.kind not in (
                inspect.Parameter.VAR_KEYWORD,
                inspect.Parameter.VAR_POSITIONAL)}
    return set(cls.parameters) | (ctor - {"in_datasets", "out_datasets"})


def _str_list(v: Any, where: str, key: str) -> tuple[str, ...]:
    if not isinstance(v, (list, tuple)) or \
            not all(isinstance(s, str) for s in v):
        raise WireError(f"{where}: {key} must be a list of dataset "
                        f"names, got {v!r}")
    return tuple(v)


def from_spec(spec: dict[str, Any]) -> ProcessList:
    """Deserialise a spec v1 document into a :class:`ProcessList`.

    Args:
        spec: parsed JSON document (``{"version": 1, "plugins": [...]}``;
            a bare list of plugin entries is accepted too).

    Returns:
        the reconstructed ProcessList (NOT yet ``check()``-ed — the
        structural chain check is the caller's admission step).

    Raises:
        WireError: malformed document, unknown plugin name (the message
            lists the registered names), unknown parameter for a plugin
            (the message lists the valid ones), or a non-JSON value
            smuggled into ``params``.
    """
    if isinstance(spec, list):
        spec = {"version": WIRE_VERSION, "plugins": spec}
    if not isinstance(spec, dict):
        raise WireError(f"spec must be a JSON object, got "
                        f"{type(spec).__name__}")
    version = spec.get("version", WIRE_VERSION)
    if version not in _ACCEPTED_VERSIONS:
        raise WireError(
            f"unsupported spec version {version!r} (this server speaks "
            f"v{'/v'.join(str(v) for v in _ACCEPTED_VERSIONS)})")
    streaming = bool(spec.get("streaming", False))
    if streaming and version < WIRE_VERSION_STREAMING:
        raise WireError('"streaming": true requires spec version >= '
                        f"{WIRE_VERSION_STREAMING}")
    entries_spec = spec.get("plugins")
    if not isinstance(entries_spec, list) or not entries_spec:
        raise WireError('spec needs a non-empty "plugins" list')

    pl = ProcessList()
    for i, e in enumerate(entries_spec):
        where = f"plugins[{i}]"
        if not isinstance(e, dict) or not isinstance(e.get("plugin"), str):
            raise WireError(f'{where}: each entry must be an object with '
                            f'a "plugin" name, got {e!r}')
        name = e["plugin"]
        cls = _REGISTRY.get(name)
        if cls is None:
            raise WireError(
                f"{where}: unknown plugin {name!r} "
                f"(registered: {sorted(_REGISTRY)})")
        params = e.get("params", {})
        if not isinstance(params, dict):
            raise WireError(f"{where} ({name}): params must be an "
                            f"object, got {params!r}")
        valid = _valid_params(cls)
        unknown = set(params) - valid
        if unknown:
            raise WireError(
                f"{where} ({name}): unknown params {sorted(unknown)} "
                f"(valid: {sorted(valid)})")
        bad = [k for k, v in params.items() if not _is_jsonable(v)]
        if bad:
            raise WireError(f"{where} ({name}): non-JSON param value(s) "
                            f"for {bad}")
        pl.add(cls, params=dict(params),
               in_datasets=_str_list(e.get("in_datasets", ()), where,
                                     "in_datasets"),
               out_datasets=_str_list(e.get("out_datasets", ()), where,
                                      "out_datasets"))
    if streaming:
        # dynamic attribute: ProcessList stays a plain dataclass and the
        # flag is deliberately NOT part of chain_signature — a streamed
        # chain shares compiled programs and checkpoints with its batch
        # twin (the final outputs are bit-identical)
        pl.streaming = True
    return pl


def to_spec(process_list: ProcessList | Iterable[PluginEntry]
            ) -> dict[str, Any]:
    """Serialise a process list to the spec v1 wire document.

    Args:
        process_list: a ProcessList (or iterable of PluginEntry) whose
            every plugin class is registered and whose params are all
            JSON-able.

    Returns:
        ``{"version": 1, "plugins": [...]}`` — round-trips through
        :func:`from_spec` to an identical chain signature.

    Raises:
        WireError: an entry's class has no wire name (register it), or
            a param value cannot be represented in JSON (e.g. a
            LambdaFilter callable — such chains are in-process only).
    """
    by_cls = {cls: name for name, cls in _REGISTRY.items()}
    out = []
    entries = (process_list.entries
               if isinstance(process_list, ProcessList) else process_list)
    for i, e in enumerate(entries):
        name = by_cls.get(e.cls)
        if name is None:
            raise WireError(
                f"entry {i}: {e.cls.__module__}.{e.cls.__qualname__} is "
                f"not wire-registered — register_plugin() it to serve it")
        bad = [k for k, v in e.params.items() if not _is_jsonable(v)]
        if bad:
            raise WireError(f"entry {i} ({name}): param(s) {bad} are not "
                            f"JSON-serialisable")
        entry: dict[str, Any] = {"plugin": name}
        if e.params:
            entry["params"] = dict(e.params)
        if e.in_datasets:
            entry["in_datasets"] = list(e.in_datasets)
        if e.out_datasets:
            entry["out_datasets"] = list(e.out_datasets)
        out.append(entry)
    if getattr(process_list, "streaming", False):
        return {"version": WIRE_VERSION_STREAMING, "streaming": True,
                "plugins": out}
    return {"version": WIRE_VERSION, "plugins": out}


def chain_plugin_names(process_list: ProcessList | Iterable[PluginEntry]
                       ) -> set[str]:
    """Wire names a worker must have registered to execute this chain —
    the broker's plugin-capability filter.  An entry whose class is not
    wire-registered maps to its python qualname, which no worker
    advertises, so such a chain is never leased out."""
    by_cls = {cls: name for name, cls in _REGISTRY.items()}
    entries = (process_list.entries
               if isinstance(process_list, ProcessList) else process_list)
    return {by_cls.get(e.cls, f"{e.cls.__module__}.{e.cls.__qualname__}")
            for e in entries}


# -- default registry: the paper's standard full-field chain ------------
def _register_defaults() -> None:
    from ..tomo import plugins as tomo
    for cls in (tomo.SyntheticTomoLoader, tomo.DarkFlatCorrection,
                tomo.PaganinFilter, tomo.RingRemoval, tomo.SinogramFilter,
                tomo.FBPRecon, tomo.HDF5LikeSaver,
                # workflow building blocks (docs/workflows.md): ingest
                # an upstream node's result, then post-process it
                tomo.UpstreamLoader, tomo.Downsample, tomo.Quantify):
        register_plugin(cls)


_register_defaults()
