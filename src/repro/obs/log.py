"""Structured event log — one bounded ring of state-transition records.

Metrics (``repro.obs.metrics``) answer "how much / how fast"; traces
(``repro.obs.trace``) answer "where did THIS job's time go".  What
neither answers is "what happened, in order, across the whole cluster" —
the question an operator asks first when a worker dies or an alert
fires.  This module is that answer: every job state transition
(``job.submit``, ``job.lease``, ``job.park``, ``job.requeue``,
``lease.expire``, ``job.complete``) and every SLO alert transition
(``alert.pending`` / ``alert.firing`` / ``alert.resolved``) appends one
JSON-able record here, and ``GET /events`` serves the ring with a
``?since=`` cursor so a client can tail it (``pipeline_serve client
events --follow``).

Every record carries ``trace_id`` / ``job_id`` / ``worker_id`` (empty
string when not applicable — alert records carry the SLO engine's own
trace id), so the event stream joins against traces and job snapshots
without guesswork.

The ring is bounded (``max_events``) with a monotonically increasing
``seq`` per record: a reader that falls behind can detect the gap
(``cursor`` < the first retained ``seq``) instead of silently missing
events.  Thread-safe; appends are O(1) and never block on I/O, so the
queue/scheduler/broker can emit from under their own locks.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Any


class EventLog:
    """Bounded, thread-safe ring of structured transition events."""

    def __init__(self, max_events: int = 2048):
        """Args:
            max_events: ring capacity; the oldest records fall off once
                exceeded (``since()`` reports the resulting gap).
        """
        if max_events < 1:
            raise ValueError(f"max_events must be >= 1, got {max_events}")
        self.max_events = max_events
        self._events: deque[dict[str, Any]] = deque(maxlen=max_events)
        self._seq = 0
        self._lock = threading.Lock()

    def emit(self, event: str, *, trace_id: str = "",
             job_id: str = "", worker_id: str = "",
             **attrs: Any) -> dict[str, Any]:
        """Append one record and return it.

        Args:
            event: dotted transition name (``job.lease``,
                ``alert.firing``...).
            trace_id: the trace this transition belongs to.  Every
                emitter is expected to supply one — the bench harness
                fails CI on records without it.
            job_id / worker_id: identities, empty when not applicable.
            attrs: free-form JSON-able annotations (state, attempt,
                rule, value...).
        """
        rec = {"event": event, "ts": time.time(),
               "trace_id": trace_id, "job_id": job_id,
               "worker_id": worker_id, "attrs": attrs}
        with self._lock:
            self._seq += 1
            rec["seq"] = self._seq
            self._events.append(rec)
        return rec

    @property
    def head(self) -> int:
        """The newest record's ``seq`` (0 while empty) — a cheap
        "anything new?" probe and the callback-gauge feed."""
        with self._lock:
            return self._seq

    def since(self, cursor: int = 0, limit: int | None = None
              ) -> dict[str, Any]:
        """Records with ``seq > cursor``, oldest first.

        Returns ``{"events": [...], "cursor": <new cursor>,
        "dropped": <n>}`` — ``cursor`` is what the caller passes next
        time (the newest served seq, or the input cursor when nothing
        new), and ``dropped`` counts records that fell off the ring
        between the caller's cursor and the first retained record (0
        for a reader that keeps up).
        """
        if cursor < 0:
            raise ValueError(f"cursor must be >= 0, got {cursor}")
        with self._lock:
            out = [e for e in self._events if e["seq"] > cursor]
            first_retained = self._events[0]["seq"] if self._events \
                else self._seq + 1
        if limit is not None and limit >= 0:
            out = out[:limit]
        new_cursor = out[-1]["seq"] if out else cursor
        dropped = max(0, first_retained - cursor - 1)
        return {"events": out, "cursor": new_cursor, "dropped": dropped}

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)
