from .analysis import (HBM_BW, ICI_BW_EFF, PEAK_FLOPS, Roofline, analyse, collective_bytes, summarise)
