"""Per-kernel shape/dtype sweeps against the pure-jnp oracles
(interpret=True executes the Pallas kernel bodies on CPU)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.backproject.kernel import backproject_pallas
from repro.kernels.backproject.ops import backproject
from repro.kernels.backproject.ref import backproject_ref
from repro.kernels.correction.kernel import correct_pallas
from repro.kernels.correction.ref import correct_ref
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import mha_chunked_ref, mha_ref
from repro.kernels.sino_filter.kernel import scale_spectrum_pallas
from repro.kernels.sino_filter.ref import filter_sino_ref, make_filter
from repro.kernels.sino_filter.ops import filter_sino


# ----------------------------------------------------------------- FBP
@pytest.mark.parametrize("A,D,N,bh,bw,ba", [
    (16, 32, 32, 8, 16, 4),
    (32, 64, 64, 8, 32, 16),
    (24, 48, 48, 16, 16, 8),
    (8, 128, 64, 8, 64, 2),
])
def test_backproject_shapes(rng, A, D, N, bh, bw, ba):
    sino = jnp.asarray(rng.normal(size=(A, D)).astype(np.float32))
    angles = jnp.linspace(0, np.pi, A, endpoint=False)
    ref = backproject_ref(sino, angles, N)
    out = backproject_pallas(sino, jnp.cos(angles).reshape(-1, 1),
                             jnp.sin(angles).reshape(-1, 1),
                             out_size=N, bh=bh, bw=bw, ba=ba,
                             interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)


def test_backproject_ops_batched(rng):
    sino = jnp.asarray(rng.normal(size=(3, 16, 32)).astype(np.float32))
    angles = jnp.linspace(0, np.pi, 16, endpoint=False)
    out = backproject(sino, angles, 32)
    assert out.shape == (3, 32, 32)
    for i in range(3):
        ref = backproject_ref(sino[i], angles, 32)
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(ref),
                                   rtol=2e-4, atol=2e-5)


def test_backproject_centre_offset(rng):
    sino = jnp.asarray(rng.normal(size=(16, 32)).astype(np.float32))
    angles = jnp.linspace(0, np.pi, 16, endpoint=False)
    ref = backproject_ref(sino, angles, 32, centre=17.5)
    out = backproject(sino, angles, 32, centre=17.5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)


# ----------------------------------------------------------- correction
@pytest.mark.parametrize("dtype", [np.uint16, np.float32])
@pytest.mark.parametrize("shape", [(2, 8, 128), (5, 33, 64), (1, 16, 256)])
def test_correction_sweep(rng, dtype, shape):
    raw = rng.integers(50, 40000, size=shape).astype(dtype)
    dark = rng.integers(80, 120, size=shape[1:]).astype(dtype)
    flat = rng.integers(30000, 42000, size=shape[1:]).astype(dtype)
    out = correct_pallas(jnp.asarray(raw), jnp.asarray(dark),
                         jnp.asarray(flat), interpret=True)
    ref = correct_ref(jnp.asarray(raw), jnp.asarray(dark)[None],
                      jnp.asarray(flat)[None])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


def test_correction_handles_dead_pixels(rng):
    raw = np.full((1, 8, 128), 0, np.uint16)          # dead detector
    dark = np.full((8, 128), 100, np.uint16)
    flat = np.full((8, 128), 100, np.uint16)           # flat == dark!
    out = correct_pallas(jnp.asarray(raw), jnp.asarray(dark),
                         jnp.asarray(flat), interpret=True)
    assert np.all(np.isfinite(np.asarray(out)))


# ----------------------------------------------------------- sino filter
@pytest.mark.parametrize("kind", ["ramlak", "shepp", "cosine", "hann"])
@pytest.mark.parametrize("F,D", [(6, 64), (3, 100), (16, 32)])
def test_sino_filter_sweep(rng, kind, F, D):
    sino = jnp.asarray(rng.normal(size=(F, D)).astype(np.float32))
    filt = jnp.asarray(make_filter(D, kind))
    a = filter_sino(sino, filt, use_pallas=True, interpret=True)
    b = filter_sino_ref(sino, filt)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


def test_scale_spectrum_kernel_direct(rng):
    re = jnp.asarray(rng.normal(size=(4, 65)).astype(np.float32))
    im = jnp.asarray(rng.normal(size=(4, 65)).astype(np.float32))
    filt = jnp.asarray(rng.normal(size=(1, 65)).astype(np.float32))
    fre, fim = scale_spectrum_pallas(re, im, filt, interpret=True)
    np.testing.assert_allclose(np.asarray(fre), np.asarray(re * filt),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(fim), np.asarray(im * filt),
                               rtol=1e-6)


# ------------------------------------------------------ flash attention
@pytest.mark.parametrize("B,Hq,Hkv,S,D", [
    (2, 4, 2, 64, 16),
    (1, 8, 1, 128, 32),
    (2, 4, 4, 32, 64),
    (1, 6, 2, 96, 16),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(rng, B, Hq, Hkv, S, D, causal):
    q = jnp.asarray(rng.normal(size=(B, Hq, S, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, Hkv, S, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, Hkv, S, D)).astype(np.float32))
    o = flash_attention_pallas(q, k, v, causal=causal, bq=32, bk=32,
                               interpret=True)
    r = mha_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), rtol=2e-5,
                               atol=2e-5)


def test_flash_attention_bf16(rng):
    q = jnp.asarray(rng.normal(size=(1, 2, 64, 32))).astype(jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 2, 64, 32))).astype(jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 2, 64, 32))).astype(jnp.bfloat16)
    o = flash_attention_pallas(q, k, v, causal=True, bq=32, bk=32,
                               interpret=True)
    r = mha_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), rtol=5e-2,
                               atol=5e-2)


def test_chunked_attention_matches_ref(rng):
    q = jnp.asarray(rng.normal(size=(2, 4, 128, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, 2, 128, 16)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, 2, 128, 16)).astype(np.float32))
    for causal in (True, False):
        a = mha_chunked_ref(q, k, v, causal=causal, block_q=32)
        b = mha_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)
