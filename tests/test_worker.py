"""Multi-host scheduling: one queue, many worker PROCESSES.

The PR acceptance path end-to-end: a broker-mode PipelineService on an
ephemeral port, two ``repro.service.worker`` subprocesses pulling jobs
over HTTP; a job SIGKILLed mid-chain on one worker finishes on the
survivor — resumed from its checkpoint (``resumed_from`` set) — with
results bit-identical to a single-process PluginRunner.  Plus the lease
state machine (expiry → requeue → exactly one owner; cancel-during-lease
→ ``cancelled`` verdict) and the capability-filter starvation
regression on ``JobQueue``.
"""
import os
import signal
import time

import numpy as np
import pytest

import slow_plugins  # noqa: F401 — registers slow_identity server-side
from repro.core import PluginRunner
from repro.service import (JobQueue, PipelineClient, PipelineService,
                           PipelineWorker, ServiceError,
                           chain_plugin_names, from_spec)
from repro.service.worker import spawn_local_workers

TESTS_DIR = os.path.dirname(os.path.abspath(__file__))
#: the standard chain's wire names — a worker WITHOUT slow_identity
PLAIN_CAPS = ["synthetic_tomo_loader", "dark_flat_correction",
              "fbp_recon", "hdf5_saver"]


def _spec(seed=0, delay=0.0, n_det=16, n_angles=8):
    """A small wire spec; ``delay`` > 0 inserts the slow_identity
    plugin (sleeps per frame) so a worker can be killed mid-chain."""
    plugins = [
        {"plugin": "synthetic_tomo_loader",
         "params": {"n_det": n_det, "n_angles": n_angles, "n_rows": 1,
                    "seed": seed},
         "out_datasets": ["tomo"]},
        {"plugin": "dark_flat_correction",
         "params": {"use_pallas": False},
         "in_datasets": ["tomo"], "out_datasets": ["tomo"]},
    ]
    if delay:
        plugins.append({"plugin": "slow_identity",
                        "params": {"delay": delay},
                        "in_datasets": ["tomo"], "out_datasets": ["tomo"]})
    plugins += [
        {"plugin": "fbp_recon", "params": {"use_pallas": False},
         "in_datasets": ["tomo"], "out_datasets": ["recon"]},
        {"plugin": "hdf5_saver", "in_datasets": ["recon"]},
    ]
    return {"version": 1, "plugins": plugins}


def _reference(spec) -> np.ndarray:
    """The single-process path for the same spec."""
    ref = PluginRunner(from_spec(spec)).run()
    return np.asarray(ref["recon"].materialise())


@pytest.fixture
def broker():
    """A broker-mode service on an ephemeral port + client (fast lease
    expiry so the race tests run in milliseconds)."""
    svc = PipelineService(workers_remote=True, lease_ttl=0.4,
                          sweep_interval=0.05)
    host, port = svc.serve(port=0)
    client = PipelineClient(f"http://{host}:{port}", timeout=30.0)
    try:
        yield svc, client
    finally:
        svc.stop()


# ===================================================== kill/resume (E2E)
def test_worker_crash_job_resumes_on_survivor(tmp_path):
    """SIGKILL the worker holding the lease mid-chain: the lease
    expires, the job requeues, the surviving worker restores the shared
    checkpoint (resumed_from > 0) and finishes — results bit-identical
    to a single-process run."""
    ckpt = str(tmp_path / "ckpts")
    svc = PipelineService(workers_remote=True, lease_ttl=1.5,
                          sweep_interval=0.1)
    host, port = svc.serve(port=0)
    url = f"http://{host}:{port}"
    client = PipelineClient(url, timeout=60.0)
    workers = spawn_local_workers(
        url, 2, transport="inmemory", checkpoint_dir=ckpt,
        poll=0.05, heartbeat=0.3, imports=("slow_plugins",),
        worker_ids=["w0", "w1"], pythonpath_extra=(TESTS_DIR,))
    by_id = dict(zip(["w0", "w1"], workers))
    try:
        spec = _spec(seed=5, delay=0.25)
        jid = client.submit(spec, job_id="crash-job")
        # wait until mid-chain: >=1 plugin done (so a checkpoint
        # exists) and the slow plugin is running on a known worker
        deadline = time.time() + 120
        while True:
            snap = client.status(jid)
            if snap["state"] == "running" and snap["plugin_index"] >= 1 \
                    and snap["worker_id"]:
                break
            assert snap["state"] not in ("done", "failed"), snap
            assert time.time() < deadline, f"never got mid-chain: {snap}"
            time.sleep(0.05)
        victim = snap["worker_id"]
        os.kill(by_id[victim].pid, signal.SIGKILL)

        snap = client.wait(jid, timeout=120)
        assert snap["state"] == "done", snap
        assert snap["resumed_from"] > 0, snap
        assert snap["worker_id"] != victim, snap
        assert snap["attempt"] >= 2, snap
        np.testing.assert_array_equal(client.result(jid),
                                      _reference(spec))
        st = client.stats()
        assert st["jobs_requeued"] >= 1
        assert st["leases_expired"] >= 1

        # the survivor keeps serving: a fresh job completes normally,
        # also bit-identical to the single-process path
        spec2 = _spec(seed=6)
        jid2 = client.submit(spec2)
        snap2 = client.wait(jid2, timeout=120)
        assert snap2["state"] == "done", snap2
        assert snap2["worker_id"] != victim
        np.testing.assert_array_equal(client.result(jid2),
                                      _reference(spec2))
    finally:
        for p in workers:
            if p.poll() is None:
                p.kill()
        for p in workers:
            p.wait(timeout=10)
        svc.stop()


# ============================================ alert lifecycle (health plane)
def test_alert_lifecycle_on_worker_kill(tmp_path):
    """The health plane end-to-end: SIGKILL the worker holding a lease
    and watch the critical ``lease-expiry-rate`` rule walk the full
    alert lifecycle — ``/healthz?ready=1`` flips to 503 while it fires
    and back to 200 once the job resumes and the rate window slides
    past the expiry; the event log records exactly one firing and one
    resolved edge, and the job's own submit→lease→expire→requeue→
    complete chain shares one trace id."""
    svc = PipelineService(
        workers_remote=True, lease_ttl=1.0, sweep_interval=0.1,
        slo_interval=0.1,
        # tighten the rate window so the rule resolves in seconds, not
        # the default 30s
        slo_spec={"lease-expiry-rate": {"window_s": 3.0}})
    host, port = svc.serve(port=0)
    url = f"http://{host}:{port}"
    client = PipelineClient(url, timeout=60.0)
    workers = spawn_local_workers(
        url, 1, transport="inmemory", poll=0.05, heartbeat=0.3,
        imports=("slow_plugins",), worker_ids=["w0"],
        pythonpath_extra=(TESTS_DIR,))
    try:
        assert client.health(ready=True)["ready"] is True
        jid = client.submit(_spec(seed=4, delay=0.3), job_id="slo-job")
        deadline = time.time() + 120
        while True:                      # wait until w0 holds the lease
            snap = client.status(jid)
            if snap["state"] == "running" and snap["worker_id"] == "w0":
                break
            assert snap["state"] not in ("done", "failed"), snap
            assert time.time() < deadline, snap
            time.sleep(0.05)
        os.kill(workers[0].pid, signal.SIGKILL)

        # lease expires -> the critical rule fires -> readiness is 503
        # with a machine-readable reason
        while True:
            health = client.health(ready=True)
            if not health["ready"]:
                break
            assert time.time() < deadline, "rule never fired"
            time.sleep(0.05)
        assert "lease-expiry-rate" in health["firing"]
        assert health["error"] == "critical SLO rule firing"
        assert client.slo()["critical_firing"] == ["lease-expiry-rate"]

        # a replacement worker drains the requeued job...
        workers += spawn_local_workers(
            url, 1, transport="inmemory", poll=0.05, heartbeat=0.3,
            imports=("slow_plugins",), worker_ids=["w1"],
            pythonpath_extra=(TESTS_DIR,))
        snap = client.wait(jid, timeout=120)
        assert snap["state"] == "done" and snap["attempt"] >= 2, snap
        # ...and once the rate window slides past the expiry the rule
        # resolves: readiness flips back to 200
        while True:
            health = client.health(ready=True)
            if health["ready"]:
                break
            assert time.time() < deadline, "rule never resolved"
            time.sleep(0.1)

        events = client.events()["events"]
        by_name = {}
        for e in events:
            by_name.setdefault(e["event"], []).append(e)
        fire = [e for e in by_name.get("alert.firing", [])
                if e["attrs"]["rule"] == "lease-expiry-rate"]
        resolved = [e for e in by_name.get("alert.resolved", [])
                    if e["attrs"]["rule"] == "lease-expiry-rate"]
        assert len(fire) == 1 and len(resolved) == 1, by_name
        assert fire[0]["trace_id"] and fire[0]["trace_id"] == \
            resolved[0]["trace_id"]
        # the job's full transition chain shares ONE trace id
        trace_id = by_name["job.submit"][0]["trace_id"]
        assert trace_id
        for name in ("job.submit", "job.lease", "lease.expire",
                     "job.requeue", "job.complete"):
            mine = [e for e in by_name.get(name, [])
                    if e["job_id"] == jid]
            assert mine, (name, sorted(by_name))
            assert all(e["trace_id"] == trace_id for e in mine), name
        assert by_name["lease.expire"][0]["worker_id"] == "w0"
        (done,) = [e for e in by_name["job.complete"]
                   if e["job_id"] == jid]
        assert done["worker_id"] == "w1"
        assert done["attrs"]["state"] == "done"
        # every record in the log carries a trace id (CI contract)
        assert all(e["trace_id"] for e in events)

        # the cluster scoreboard shows the dead worker's staleness and
        # the survivor with no active leases
        cluster = client.cluster()
        by_worker = {w["worker_id"]: w for w in cluster["workers"]}
        assert set(by_worker) == {"w0", "w1"}
        assert by_worker["w1"]["jobs_done"] >= 1
        assert by_worker["w1"]["leases"] == []
        assert by_worker["w0"]["heartbeat_staleness_s"] > 1.0
        assert cluster["leases_expired"] >= 1
    finally:
        for p in workers:
            if p.poll() is None:
                p.kill()
        for p in workers:
            p.wait(timeout=10)
        svc.stop()


# ================================================== lease state machine
def test_lease_expiry_exactly_one_owner(broker):
    """A heartbeat after expiry is rejected; after the requeue exactly
    one worker owns the job and the stale owner's complete/upload are
    discarded with 409."""
    svc, client = broker
    client.register_worker(worker_id="w1")
    client.register_worker(worker_id="w2")
    jid = client.submit(_spec(seed=1))
    leased = client.lease("w1")
    assert [d["job_id"] for d in leased] == [jid]
    assert leased[0]["attempt"] == 1
    assert leased[0]["process_list"]["plugins"][0]["params"]["seed"] == 1
    # double-lease of the same job is impossible while it is leased
    assert client.lease("w2") == []
    assert client.lease("w1") == []

    time.sleep(0.8)                      # ttl 0.4s: expired and swept
    assert client.progress(jid, "w1", plugin_index=1)["verdict"] == "lost"
    assert client.status(jid)["state"] in ("queued", "checking")

    l2 = client.lease("w2")              # exactly one new owner
    assert [d["job_id"] for d in l2] == [jid]
    assert l2[0]["attempt"] == 2
    assert client.lease("w1") == []
    assert client.progress(jid, "w1")["verdict"] == "lost"
    assert client.progress(jid, "w2", plugin_index=0)["verdict"] == "ok"
    # the stale owner's outcome is void: complete and upload are 409
    with pytest.raises(ServiceError) as ei:
        client.complete(jid, "w1", "done")
    assert ei.value.status == 409
    with pytest.raises(ServiceError) as ei:
        client.upload_result(jid, "w1", "recon", b"\x93NUMPY...")
    assert ei.value.status == 409


def test_unsafe_names_rejected(broker):
    """worker_id and result dataset names become path components on
    the broker — separators and dot-leading names are refused with
    400 before they reach the filesystem."""
    svc, client = broker
    for bad in ("../evil", "a/b", "/abs", ".."):
        with pytest.raises(ServiceError) as ei:
            client.register_worker(worker_id=bad)
        assert ei.value.status == 400
    client.register_worker(worker_id="w1")
    jid = client.submit(_spec(seed=3))
    assert client.lease("w1")
    for bad in ("../../etc/evil", "..", "a/b"):
        with pytest.raises(ServiceError) as ei:
            client.upload_result(jid, "w1", bad, b"x")
        assert ei.value.status == 400


def test_cancel_during_lease_yields_cancelled_verdict(broker):
    svc, client = broker
    client.register_worker(worker_id="w1")
    jid = client.submit(_spec(seed=2))
    assert client.lease("w1")
    assert client.progress(jid, "w1", plugin_index=0,
                           n_plugins=3)["verdict"] == "ok"
    out = client.cancel(jid)
    assert out["cancelled"] is True and out.get("pending") is True
    # the job is not terminal until the worker is told to stop...
    assert client.progress(jid, "w1",
                           plugin_index=1)["verdict"] == "cancelled"
    assert client.status(jid)["state"] == "cancelled"
    # ...and the lease is gone with it
    assert client.progress(jid, "w1")["verdict"] == "lost"
    with pytest.raises(ServiceError) as ei:
        client.complete(jid, "w1", "done")
    assert ei.value.status == 409


def test_requeued_job_leases_in_priority_order(broker):
    """An expired lease's job re-enters at the front of its priority
    class (oldest seq), ahead of later same-priority submissions."""
    svc, client = broker
    client.register_worker(worker_id="w1")
    j1 = client.submit(_spec(seed=1))
    assert [d["job_id"] for d in client.lease("w1")] == [j1]
    j2 = client.submit(_spec(seed=2))
    time.sleep(0.8)                      # j1's lease expires, requeued
    got = client.lease("w1", max_jobs=1)
    assert [d["job_id"] for d in got] == [j1], (got, j2)


# ============================================ capability filters & leases
def test_capability_filter_routes_jobs(broker):
    """plugins / mesh_shape capability filters decide which worker may
    lease which job."""
    svc, client = broker
    client.register_worker(worker_id="plain", plugins=PLAIN_CAPS)
    client.register_worker(worker_id="full")      # unrestricted
    jid = client.submit(_spec(seed=1, delay=0.01))   # needs slow_identity
    assert client.lease("plain") == []   # can't run slow_identity
    assert [d["job_id"] for d in client.lease("full")] == [jid]

    # mesh capacity: a job demanding 4 devices skips a 1-device worker
    client.register_worker(worker_id="small", mesh_shape=[1])
    client.register_worker(worker_id="big", mesh_shape=[2, 2])
    jm = client.submit(_spec(seed=2), metadata={"mesh_shape": [4]})
    assert client.lease("small") == []
    assert [d["job_id"] for d in client.lease("big")] == [jm]


def test_capability_starvation_regression(broker):
    """An unmatchable high-priority head must not shadow matchable
    lower-priority jobs: the restricted worker keeps draining its
    matchable jobs in FIFO order while the head waits for a capable
    worker (two capability sets, as in the PR checklist)."""
    svc, client = broker
    client.register_worker(worker_id="plain", plugins=PLAIN_CAPS)
    client.register_worker(worker_id="full")
    j_slow = client.submit(_spec(seed=1, delay=0.01), priority=10)
    j_plain = [client.submit(_spec(seed=s)) for s in (2, 3, 4)]
    # the plain worker drains ITS jobs FIFO, never blocked by j_slow
    for expect in j_plain:
        got = client.lease("plain")
        assert [d["job_id"] for d in got] == [expect]
    assert client.lease("plain") == []   # only the unmatchable one left
    assert client.status(j_slow)["state"] == "queued"
    # the capable worker still sees priority order: j_slow first
    assert [d["job_id"] for d in client.lease("full")] == [j_slow]


def test_queue_predicate_pop_is_starvation_safe():
    """JobQueue.get(predicate=...) regression: scan past an unmatchable
    head without disturbing it, repeatedly."""
    q = JobQueue()
    a = q.submit(from_spec(_spec(seed=0, delay=0.01)), priority=5)
    b = q.submit(from_spec(_spec(seed=1)), priority=0)
    c = q.submit(from_spec(_spec(seed=2)), priority=0)
    caps = set(PLAIN_CAPS)
    pred = lambda j: chain_plugin_names(j.process_list) <= caps  # noqa: E731
    assert q.get(timeout=0, predicate=pred) is b   # skips head a, FIFO
    assert q.get(timeout=0, predicate=pred) is c
    assert q.get(timeout=0, predicate=pred) is None  # a never matched
    assert q.get(timeout=0) is a        # ...and kept its queue position
    # get_batch honours the predicate for head + gang members too
    d = q.submit(from_spec(_spec(seed=3)))
    e = q.submit(from_spec(_spec(seed=3, delay=0.01)))
    batch = q.get_batch(4, timeout=0, match=lambda x, y: True,
                        predicate=pred)
    assert batch == [d]                 # e filtered out of the gang


def test_batch_lease_renews_pending_mates(broker):
    """A worker leasing max_batch jobs runs them sequentially; the
    heartbeat must renew the WAITING jobs' leases too (ttl here is
    0.4s, well under the first job's runtime), so none are requeued."""
    svc, client = broker
    ids = [client.submit(_spec(seed=s)) for s in range(3)]
    w = PipelineWorker(client.base_url, worker_id="batch-w",
                       max_batch=3, poll=0.01, heartbeat=0.1)
    w.register()
    assert w.run_once() is True
    assert [client.status(j)["state"] for j in ids] == ["done"] * 3
    st = client.stats()
    assert st["jobs_requeued"] == 0 and st["leases_expired"] == 0
    for i, j in enumerate(ids):
        np.testing.assert_array_equal(client.result(j),
                                      _reference(_spec(seed=i)))


def test_queue_predicate_scan_reaps_cancelled_tombstones():
    """Broker-mode pops always pass a predicate; cancelled jobs' heap
    entries must be reaped by the scan, not linger forever."""
    q = JobQueue()
    a = q.submit(from_spec(_spec(seed=0)))
    b = q.submit(from_spec(_spec(seed=1)))
    assert q.cancel(a.job_id) is True
    assert q.get(timeout=0, predicate=lambda j: True) is b
    assert q._heap == []                # tombstone reaped with the pop


def test_shared_fs_results_and_outside_paths_refused(broker):
    """Shared-fs hand-off works end-to-end, and a complete() naming a
    path OUTSIDE the broker results_dir is refused."""
    svc, client = broker
    spec = _spec(seed=8)
    jid = client.submit(spec)
    w = PipelineWorker(client.base_url, worker_id="fs-w", poll=0.01,
                       shared_fs=True)
    w.register()
    assert w.results_dir == svc.broker.results_dir
    assert w.run_once() is True
    np.testing.assert_array_equal(client.result(jid), _reference(spec))

    j2 = client.submit(_spec(seed=9))
    # acting on fs-w's behalf needs fs-w's minted secret
    client.adopt_worker_secret("fs-w", w.client.worker_secret("fs-w"))
    assert client.lease("fs-w")
    with pytest.raises(ServiceError) as ei:
        client.complete(j2, "fs-w", "done",
                        results={"recon": {"path": "/etc/hostname"}})
    assert ei.value.status == 400


# ====================================================== in-process worker
def test_inprocess_worker_round_trip(broker):
    """PipelineWorker as a library (no subprocess): register, lease,
    run, upload; the broker serves the result and per-worker stats."""
    svc, client = broker
    spec = _spec(seed=7)
    jid = client.submit(spec)
    w = PipelineWorker(client.base_url, worker_id="lib-w", poll=0.01)
    w.register()
    assert w.run_once() is True
    snap = client.status(jid)
    assert snap["state"] == "done" and snap["worker_id"] == "lib-w"
    np.testing.assert_array_equal(client.result(jid), _reference(spec))
    workers = client.workers()
    assert workers["lib-w"]["jobs_done"] == 1
    assert client.stats()["jobs_done"] == 1
