"""Per-plugin profiler — the MPI-profiler analogue (paper §IV.B, Fig 9).

Savu ships a profiler that visualises, per MPI process, the time each
processing step took.  Here every plugin execution records wall time per
phase (setup / pre / process / post), the participating device count,
and — when the sharded transport provides a compiled artifact — the HLO
FLOPs and bytes from ``cost_analysis()``.  ``report()`` renders the
Fig-9-style ASCII bar chart; ``save()`` emits JSON for the benchmark
harness.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Any


@dataclasses.dataclass
class Event:
    plugin: str
    phase: str          # 'setup' | 'pre' | 'process' | 'post' | 'io'
    start: float
    end: float
    devices: int = 1
    flops: float | None = None
    bytes: float | None = None
    extra: dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def wall(self) -> float:
        return self.end - self.start


class Profiler:
    def __init__(self):
        self.events: list[Event] = []
        self._t0 = time.perf_counter()

    # ------------------------------------------------------------------
    def record(self, plugin: str, phase: str, start: float, end: float,
               devices: int = 1, flops=None, bytes=None, **extra) -> None:
        self.events.append(Event(plugin, phase, start, end, devices,
                                 flops, bytes, extra))

    class _Timer:
        def __init__(self, prof, plugin, phase, devices, extra):
            self.prof, self.plugin, self.phase = prof, plugin, phase
            self.devices, self.extra = devices, extra

        def __enter__(self):
            self.start = time.perf_counter()
            return self

        def __exit__(self, *exc):
            self.prof.record(self.plugin, self.phase, self.start,
                             time.perf_counter(), self.devices,
                             **self.extra)
            return False

    def timer(self, plugin: str, phase: str, devices: int = 1, **extra):
        return Profiler._Timer(self, plugin, phase, devices, extra)

    # ------------------------------------------------------------------
    def totals(self) -> dict[str, float]:
        out: dict[str, float] = {}
        for e in self.events:
            out[e.plugin] = out.get(e.plugin, 0.0) + e.wall
        return out

    def report(self, width: int = 50) -> str:
        """Fig-9-style per-plugin bar chart."""
        totals = self.totals()
        if not totals:
            return "(no events)"
        tmax = max(totals.values()) or 1.0
        lines = [f"{'plugin':<32} {'wall(s)':>9}  profile"]
        for name, t in totals.items():
            bar = "#" * max(1, int(width * t / tmax))
            lines.append(f"{name:<32} {t:9.4f}  {bar}")
        phases: dict[str, float] = {}
        for e in self.events:
            phases[e.phase] = phases.get(e.phase, 0.0) + e.wall
        lines.append("")
        lines.append("per-phase: " + "  ".join(
            f"{k}={v:.4f}s" for k, v in sorted(phases.items())))
        return "\n".join(lines)

    def save(self, path: str) -> None:
        with open(path, "w") as fh:
            json.dump([dataclasses.asdict(e) for e in self.events], fh,
                      indent=2, default=str)

    @staticmethod
    def load(path: str) -> "Profiler":
        p = Profiler()
        with open(path) as fh:
            for d in json.load(fh):
                extra = d.pop("extra", {})
                p.events.append(Event(**d, extra=extra))
        return p
