#!/usr/bin/env python
"""Docs checker (run by the CI docs job).

Two guarantees over `docs/*.md`, `ARCHITECTURE.md`, `ROADMAP.md` and
`README.md` (where present):

1. every RELATIVE markdown link `[text](path)` resolves to an existing
   file (http/mailto/anchor-only links are skipped, `#fragment`s are
   stripped);
2. every fenced ```python block parses: blocks are extracted to a temp
   directory and byte-compiled with `compileall`, so documented
   examples cannot rot into syntax errors.

Exit status 0 = clean; 1 = problems (listed on stderr).
"""
from __future__ import annotations

import compileall
import pathlib
import re
import sys
import tempfile

ROOT = pathlib.Path(__file__).resolve().parent.parent
DOC_FILES = sorted(ROOT.glob("docs/*.md")) + [
    p for p in (ROOT / "ARCHITECTURE.md", ROOT / "ROADMAP.md",
                ROOT / "README.md") if p.exists()]

#: [text](target) — target up to the first ')' or whitespace
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"^```python\s*$(.*?)^```\s*$",
                      re.MULTILINE | re.DOTALL)
CODE_BLOCK_RE = re.compile(r"^```.*?^```\s*$", re.MULTILINE | re.DOTALL)


def check_links(md: pathlib.Path) -> list[str]:
    """Relative links in ``md`` that do not resolve on disk."""
    # don't treat `](` sequences inside fenced code as links
    text = CODE_BLOCK_RE.sub("", md.read_text())
    errors = []
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        resolved = (md.parent / path).resolve()
        if not resolved.exists():
            errors.append(f"{md.relative_to(ROOT)}: broken link "
                          f"-> {target}")
    return errors


def extract_python_blocks(md: pathlib.Path) -> list[str]:
    return [m.group(1) for m in FENCE_RE.finditer(md.read_text())]


def main() -> int:
    errors: list[str] = []
    n_blocks = 0
    with tempfile.TemporaryDirectory(prefix="check_docs_") as tmp:
        tmpdir = pathlib.Path(tmp)
        for md in DOC_FILES:
            errors.extend(check_links(md))
            for i, block in enumerate(extract_python_blocks(md)):
                stem = md.relative_to(ROOT).as_posix().replace("/", "_")
                (tmpdir / f"{stem}_{i}.py").write_text(block)
                n_blocks += 1
        if n_blocks and not compileall.compile_dir(str(tmpdir), quiet=1):
            errors.append(
                "python snippet(s) failed to compile (filenames above "
                "map back to <doc>_<block-index>)")
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked {len(DOC_FILES)} docs, {n_blocks} python blocks: "
          f"{'FAIL' if errors else 'ok'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
