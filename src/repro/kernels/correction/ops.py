"""Public wrapper for the fused correction kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import correct_pallas
from .ref import EPS, HI, correct_ref


@functools.partial(jax.jit, static_argnames=("eps", "hi", "use_pallas",
                                             "interpret"))
def correct(raw: jnp.ndarray, dark: jnp.ndarray, flat: jnp.ndarray,
            eps: float = EPS, hi: float = HI, *, use_pallas: bool = True,
            interpret: bool = True) -> jnp.ndarray:
    """(..., Y, X) raw + (Y, X) dark/flat -> (..., Y, X) −log corrected."""
    lead = raw.shape[:-2]
    y, x = raw.shape[-2:]
    flatr = raw.reshape((-1, y, x))
    if use_pallas:
        out = correct_pallas(flatr, dark, flat, eps=eps, hi=hi,
                             interpret=interpret)
    else:
        out = correct_ref(flatr, dark[None], flat[None], eps, hi)
    return out.reshape(lead + (y, x))
