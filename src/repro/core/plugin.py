"""Plugin base classes + drivers (paper §III.F).

A plugin is an independent processing step.  It declares how many
in/out datasets it needs, sets up its out_datasets (shape, axis labels,
patterns) in ``setup``, and implements a pure ``process_frames`` that
maps m input frames -> m output frames.  The framework owns all data
movement; the plugin never sees more than its requested frames.

Drivers (paper §III.F.1): the CPU driver lets every process run the
plugin; the GPU driver restricts execution to a sub-communicator.  In
the mesh adaptation a driver names the mesh axes the plugin's jit may
shard over — ``MeshDriver(axes=("data",))`` is the CPU-driver analogue
(everyone participates along ``data``); a reduced driver such as
``MeshDriver(axes=("model",))`` or a sub-mesh driver reproduces the
GPU-communicator behaviour.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Callable, Sequence

import numpy as np

from .dataset import DataSet


@dataclasses.dataclass(frozen=True)
class MeshDriver:
    """Names the mesh axes a plugin distributes over."""
    axes: tuple[str, ...] = ("data",)
    #: run on a sub-mesh only (e.g. GPU-driver analogue); empty = all
    submesh: dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def data_axis(self) -> str | None:
        return self.axes[0] if self.axes else None


CPU_DRIVER = MeshDriver(axes=("data",))
GPU_DRIVER = MeshDriver(axes=("data",), submesh={"model": 1})


@dataclasses.dataclass
class PluginData:
    """Per-plugin view onto a dataset (paper §III.F.4): which access
    pattern and how many frames per processing call."""
    dataset: DataSet
    pattern_name: str = ""
    n_frames: int = 1
    #: frame-padding in core dims: {axis_label: pad} (framework applies)
    padding: dict[str, int] = dataclasses.field(default_factory=dict)
    #: True when this plugin step is the dataset's FINAL consumer — the
    #: runner sets it from its liveness analysis in ``begin_step``; a
    #: donating transport may only donate an input buffer whose view has
    #: ``last_use=True`` (a branching chain reads it again otherwise).
    #: Defaults to True so direct transport use keeps eager donation.
    last_use: bool = True

    @property
    def pattern(self):
        return self.dataset.get_pattern(self.pattern_name)


class BasePlugin:
    """Base of all plugins.  Subclass one of BaseFilter/BaseRecon/
    BaseLoader/BaseSaver rather than this directly."""

    name: str = "base_plugin"
    n_in_datasets: int = 1
    n_out_datasets: int = 1
    #: pattern for out_datasets when it differs from the input pattern
    #: (e.g. recon: SINOGRAM in, VOLUME_XZ out); None = same as input.
    out_pattern_name: str | None = None
    driver: MeshDriver = CPU_DRIVER
    #: user-tunable parameters with defaults; overridden per process-list
    parameters: dict[str, Any] = {}
    #: params that select WHICH data is processed (file path, scan seed)
    #: rather than HOW — excluded from the chain signature so jobs over
    #: different datasets still count as "the same pipeline"
    data_params: tuple[str, ...] = ()
    #: *tunable* params — Savu-style parameter-tuning candidates (filter
    #: cutoff, Paganin tau, ring strength...).  Declaring a param here is
    #: the same contract as ``data_params``: its effect on
    #: ``process_frames`` flows ONLY through :meth:`jit_constants`
    #: (arrays/floats built in ``setup``), never as a static trace-time
    #: value.  Tunables are excluded from both the chain signature and
    #: the compile-cache signature, so a parameter sweep expands into
    #: variant jobs with IDENTICAL chains that gang-batch and share one
    #: compiled program (see ``repro.service.sweep``).
    tunable_params: tuple[str, ...] = ()
    #: instance attrs that must stay trace-time constants even though
    #: they are arrays/floats (e.g. a float used in python control flow
    #: inside process_frames) — excluded from jit_constants and folded
    #: into the cache key instead
    static_attrs: tuple[str, ...] = ()

    def __init__(self, **params):
        self.params = {**self.__class__.parameters}
        unknown = set(params) - set(self.params) - {"in_datasets",
                                                    "out_datasets"}
        if unknown:
            raise ValueError(
                f"plugin {self.name!r}: unknown parameters {sorted(unknown)} "
                f"(valid: {sorted(self.params)})")
        self.params.update({k: v for k, v in params.items()
                            if k not in ("in_datasets", "out_datasets")})
        #: dataset names, filled from the process list at check time
        self.in_dataset_names: list[str] = list(params.get("in_datasets", []))
        self.out_dataset_names: list[str] = list(params.get("out_datasets", []))
        #: PluginData views, attached by the framework when plugged in
        self.in_data: list[PluginData] = []
        self.out_data: list[PluginData] = []

    # -- mandatory interface ------------------------------------------
    def setup(self, in_datasets: list[DataSet]) -> list[DataSet]:
        """Describe out_datasets given in_datasets, and set the pattern +
        n_frames on every PluginData.  Default: single in -> single out of
        identical shape, same patterns, first pattern, 1 frame."""
        (din,) = in_datasets
        dout = din.like(self.out_dataset_names[0])
        pat = self.default_pattern(din)
        self.chunk_frames(pat)
        return [dout]

    def process_frames(self, frames: Sequence[Any]) -> Any:
        """Pure function: list of per-in-dataset frame blocks -> per-out
        blocks.  Each block has shape (m, *core_shape).  Must be jax-
        traceable for the sharded transport."""
        raise NotImplementedError

    # -- optional hooks -------------------------------------------------
    def pre_process(self) -> None:  # once, before the frame loop
        pass

    def post_process(self) -> None:  # once, after an implicit barrier
        pass

    # -- helpers ---------------------------------------------------------
    def default_pattern(self, din: DataSet) -> str:
        if not din.patterns:
            raise ValueError(f"dataset {din.name!r} has no patterns")
        return next(iter(din.patterns))

    def chunk_frames(self, pattern_name: str, n_frames: int = 1) -> None:
        """Set pattern/nframes on all attached PluginData (in then out)."""
        for pd in self.in_data + self.out_data:
            pd.pattern_name = pattern_name
            pd.n_frames = n_frames

    def get_param(self, key: str):
        return self.params[key]

    @classmethod
    def param_spec(cls) -> dict[str, Any]:
        """Introspect this plugin class for the service layer's wire
        format (``repro.service.wire``): declared parameters with their
        defaults, which of them are ``data_params``, and the dataset
        arity.  Everything returned is JSON-serialisable so a remote
        client can discover the registry via ``GET /plugins``.

        Returns:
            dict with ``name`` (wire name), ``doc`` (first docstring
            line), ``n_in_datasets``/``n_out_datasets``, and ``params``
            mapping each parameter to ``{"default", "data_param",
            "sweepable"}`` (non-JSON defaults are shown as their
            ``repr``; ``sweepable`` marks ``tunable_params`` — the only
            ones a parameter sweep may grid over).
        """
        params = {}
        for k, v in cls.parameters.items():
            params[k] = {"default": v if _is_jsonable(v) else repr(v),
                         "data_param": k in cls.data_params,
                         "sweepable": k in cls.tunable_params}
        doc = (cls.__doc__ or "").strip().splitlines()
        return {"name": cls.name,
                "doc": doc[0] if doc else "",
                "n_in_datasets": cls.n_in_datasets,
                "n_out_datasets": cls.n_out_datasets,
                "params": params}

    # -- compile-cache support (service layer) --------------------------
    #: instance attrs that never feed process_frames
    _NON_CONST_ATTRS = frozenset({
        "params", "in_dataset_names", "out_dataset_names",
        "in_data", "out_data"})

    def jit_constants(self) -> dict[str, Any]:
        """Setup-derived values that ``process_frames`` reads off ``self``
        and that VARY with the input data (dark/flat fields, filter
        banks, angles, scalar calibrations...).  The sharded transport
        passes these as jit *arguments* rather than letting them bake in
        as trace-time constants, so one compiled function serves every
        plugin instance with the same :meth:`cache_signature` — the
        paper's "same pipeline, many datasets" case.

        Default: every instance attribute that is an array or a python
        float.  ints/strs/bools stay static (they select shapes/branches)
        and are folded into :meth:`cache_signature` instead."""
        consts: dict[str, Any] = {}
        for k, v in vars(self).items():
            if k in self._NON_CONST_ATTRS or k in self.static_attrs:
                continue
            if isinstance(v, np.ndarray) or (
                    hasattr(v, "dtype") and hasattr(v, "shape")
                    and hasattr(v, "__array__") and not isinstance(v, DataSet)):
                consts[k] = v
            elif isinstance(v, float) and not isinstance(v, bool):
                consts[k] = v
        return consts

    def cache_signature(self) -> tuple:
        """Hashable static identity of this plugin for the compile cache:
        class + jsonable params + static (int/str/bool/None) attrs.  Two
        instances with equal signatures, equal in/out dataset specs and
        structurally-equal :meth:`jit_constants` may share one compiled
        function.  ``data_params`` and ``tunable_params`` are excluded:
        declaring a param in either is a contract that its effect on
        ``process_frames`` flows ONLY through :meth:`jit_constants`
        (arrays/floats built in setup), never as a static trace-time
        value — which is what lets a parameter sweep's variants share
        one compiled program."""
        sig_params: dict[str, Any] = {}
        unsignable: list[tuple] = []
        for k, v in sorted(self.params.items()):
            if k in self.data_params or k in self.tunable_params:
                continue
            if _is_jsonable(v):
                sig_params[k] = v
            else:
                # a param we cannot fingerprint (callable, object...) —
                # pin the entry to THIS instance's value rather than
                # silently sharing a compiled program across different
                # behaviours; declare it in data_params if it is data
                unsignable.append((k, type(v).__qualname__, id(v)))
        params_j = json.dumps(sig_params, sort_keys=True)
        statics = tuple(
            (k, repr(v))
            for k, v in sorted(vars(self).items())
            if k not in self._NON_CONST_ATTRS
            and (isinstance(v, (bool, int, str, type(None)))
                 or k in self.static_attrs
                 # jsonable containers (e.g. a kernel list derived in
                 # setup) are trace-time constants too — key on them so
                 # differing values never share a program
                 or (isinstance(v, (list, tuple, dict))
                     and _is_jsonable(v))))
        return (f"{type(self).__module__}.{type(self).__qualname__}",
                params_j, tuple(unsignable), statics)

    def __repr__(self):
        return f"{type(self).__name__}({self.name})"


def _is_jsonable(v) -> bool:
    try:
        json.dumps(v)
        return True
    except TypeError:
        return False


class BaseFilter(BasePlugin):
    """1-in 1-out, same shape — the common filter plugin type."""
    name = "base_filter"
    pattern_name: str | None = None   # subclass fixes its space
    frames: int = 1

    def setup(self, in_datasets):
        (din,) = in_datasets
        dout = din.like(self.out_dataset_names[0])
        pat = self.pattern_name or self.default_pattern(din)
        self.chunk_frames(pat, self.frames)
        return [dout]


class BaseRecon(BasePlugin):
    """Sinogram-in, volume-slice-out reconstruction plugins."""
    name = "base_recon"


class BaseLoader(BasePlugin):
    """Creates DataSets lazily (paper: loader loads *information*, not
    data).  ``load`` returns fully-described datasets whose backing may be
    a thunk."""
    name = "base_loader"
    n_in_datasets = 0

    def setup(self, in_datasets):  # loaders use load() instead
        raise RuntimeError("loaders use .load()")

    def load(self) -> list[DataSet]:
        raise NotImplementedError

    def process_frames(self, frames):
        raise RuntimeError("loaders do not process frames")


class BaseSaver(BasePlugin):
    """Persists datasets; called after loaders, retains a link with the
    framework until the chain completes (paper §III.F.2)."""
    name = "base_saver"
    n_out_datasets = 0

    def setup(self, in_datasets):
        self.chunk_frames(self.default_pattern(in_datasets[0]))
        return []

    def create(self, dataset: DataSet, now, next_) -> None:
        """Allocate backing storage for an out_dataset (chunked)."""
        raise NotImplementedError

    def save(self, dataset: DataSet) -> None:
        raise NotImplementedError

    def process_frames(self, frames):
        raise RuntimeError("savers do not process frames")


# ----------------------------------------------------------------------
class LambdaFilter(BaseFilter):
    """Quick functional filter: wraps fn(block)->block (testing/examples)."""
    name = "lambda_filter"

    def __init__(self, fn: Callable, pattern: str | None = None,
                 frames: int = 1, out_dtype=None, **params):
        super().__init__(**params)
        self._fn = fn
        self.pattern_name = pattern
        self.frames = frames
        self._out_dtype = out_dtype

    def setup(self, in_datasets):
        (din,) = in_datasets
        dout = din.like(self.out_dataset_names[0],
                        dtype=self._out_dtype or din.dtype)
        pat = self.pattern_name or self.default_pattern(din)
        self.chunk_frames(pat, self.frames)
        return [dout]

    def process_frames(self, frames):
        return self._fn(frames[0])

    _fn_tokens = iter(range(1, 1 << 62))

    def cache_signature(self):
        # the wrapped callable is invisible to the default signature;
        # pin the cache entry to this exact function object via a token
        # stored ON the function (id() values can be recycled after GC,
        # which would alias a dead lambda's compiled program)
        try:
            token = self._fn.__savu_cache_token__
        except AttributeError:
            token = next(LambdaFilter._fn_tokens)
            try:
                self._fn.__savu_cache_token__ = token
            except (AttributeError, TypeError):
                token = ("id", id(self._fn))   # unpinnable callable
        return super().cache_signature() + (
            ("fn", getattr(self._fn, "__qualname__", "?"), token),)
