"""Parameter sweeps — Savu's *parameter tuning* as a service workload.

Savu's headline usability feature: give a plugin parameter a LIST of
values and the framework re-runs that stage per value, adding an extra
dimension to the data so users can pick the best reconstruction
(classically the centre-of-rotation / filter cutoff for FBP).  The
service layer makes this fast at scale:

* a spec-v1 process list plus a ``sweep`` block (grid over ≤2
  *tunable* params) expands into a :class:`SweepGroup` of variant jobs
  whose chain signatures are IDENTICAL — tunables are excluded from
  both the chain signature and the compile-cache signature, their
  effect riding in ``jit_constants`` as runtime arguments;
* the variants are admitted **atomically** (``JobQueue.submit_many``),
  so the existing gang-batching scheduler pops them as one gang: each
  plugin step is ONE compiled call over every variant, and an N-point
  sweep compiles each plugin exactly once;
* group-level lifecycle rides over HTTP (``POST /sweeps``,
  ``GET /sweeps/{id}``, ``GET /sweeps/{id}/result`` — the stacked
  ``.npy`` with the parameter axis as the new leading dimension —
  ``DELETE /sweeps/{id}``), with an optional per-variant quality
  ``metric`` surfaced as ``best_variant``.

Sweep block (one axis, or a list of ≤2 for a grid)::

    {"process_list": {spec v1},
     "sweep": {"plugin": "sinogram_filter",   # or "plugin_index": 3
               "param": "cutoff",
               "values": [0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0]},
     "metric": "sharpness"}

Only params a plugin declares in ``tunable_params`` (shown as
``sweepable`` in ``BasePlugin.param_spec()`` / ``GET /plugins``) may be
swept — anything else changes the compiled program and is rejected
loudly with the sweepable alternatives.  See ``docs/sweeps.md``.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from typing import Any, Callable, Iterable

import numpy as np

from ..core.plugin import _is_jsonable
from ..core.process_list import PluginEntry, ProcessList
from .job import Job
from .queue import JobQueue
from .wire import from_spec

#: grid dimensionality bound — Savu sweeps one or two params at a time
MAX_AXES = 2


class SweepError(ValueError):
    """A sweep request cannot be expanded: malformed block, unknown
    plugin/param, a non-sweepable param, too many axes/variants, or an
    unknown metric (HTTP 400)."""


# ----------------------------------------------------------------------
# metrics: per-variant quality scores over the result volume
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Metric:
    """A per-variant quality score.  ``best_variant`` maximises the
    score when ``higher_is_better`` else minimises it."""

    fn: Callable[[np.ndarray], float]
    higher_is_better: bool
    doc: str


def _sharpness(a: np.ndarray) -> float:
    """Mean gradient magnitude — sharp, well-tuned reconstructions have
    strong edges."""
    a = np.asarray(a, dtype=np.float64)
    g = np.zeros_like(a)
    for ax in range(a.ndim):
        d = np.diff(a, axis=ax)
        pad = [(0, 0)] * a.ndim
        pad[ax] = (0, 1)
        g += np.pad(d, pad) ** 2
    return float(np.mean(np.sqrt(g)))


def _entropy(a: np.ndarray, bins: int = 256) -> float:
    """Shannon entropy of the intensity histogram — a well-tuned
    reconstruction concentrates intensity (lower entropy)."""
    a = np.asarray(a, dtype=np.float64).ravel()
    hist, _ = np.histogram(a, bins=bins)
    p = hist / max(1, hist.sum())
    p = p[p > 0]
    return float(-np.sum(p * np.log2(p)))


def _std(a: np.ndarray) -> float:
    """Standard deviation — contrast proxy."""
    return float(np.std(np.asarray(a, dtype=np.float64)))


METRICS: dict[str, Metric] = {
    "sharpness": Metric(_sharpness, True, "mean gradient magnitude "
                        "(higher = sharper edges)"),
    "entropy": Metric(_entropy, False, "histogram entropy "
                      "(lower = more concentrated intensity)"),
    "std": Metric(_std, True, "standard deviation (higher = more "
                  "contrast)"),
}


# ----------------------------------------------------------------------
# sweep block parsing + expansion
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SweepAxis:
    """One grid axis: sweep ``param`` of the ``plugin_index``-th process
    list entry over ``values``."""

    plugin_index: int
    param: str
    values: tuple
    label: str          # "<wire name>.<param>" for snapshots/CLI

    def spec(self) -> dict[str, Any]:
        return {"plugin_index": self.plugin_index, "param": self.param,
                "values": list(self.values), "label": self.label}


def parse_sweep_block(block: Any, process_list: ProcessList
                      ) -> list[SweepAxis]:
    """Validate a ``sweep`` block against the process list.

    Args:
        block: one axis object or a list of ≤ :data:`MAX_AXES` of them;
            each needs ``param``, ``values``, and ``plugin_index`` (or a
            unique ``plugin`` wire name).
        process_list: the chain the axes index into.

    Returns: the validated axes.
    Raises:
        SweepError: malformed block, unresolvable plugin, unknown or
            non-sweepable param (the message names the sweepable ones),
            bad values.
    """
    if isinstance(block, dict):
        block = [block]
    if not isinstance(block, list) or not block:
        raise SweepError('"sweep" must be an axis object or a non-empty '
                         'list of them')
    if len(block) > MAX_AXES:
        raise SweepError(f"at most {MAX_AXES} sweep axes are supported, "
                         f"got {len(block)}")
    axes: list[SweepAxis] = []
    for i, ax in enumerate(block):
        where = f"sweep[{i}]"
        if not isinstance(ax, dict):
            raise SweepError(f"{where}: each axis must be an object, "
                             f"got {ax!r}")
        entry, idx = _resolve_entry(ax, process_list, where)
        param = ax.get("param")
        if not isinstance(param, str):
            raise SweepError(f'{where}: needs a string "param"')
        spec = entry.cls.param_spec()["params"]
        if param not in spec:
            raise SweepError(
                f"{where}: plugin {entry.cls.name!r} has no parameter "
                f"{param!r} (declared: {sorted(spec)})")
        if not spec[param].get("sweepable"):
            sweepable = sorted(k for k, v in spec.items()
                               if v.get("sweepable"))
            raise SweepError(
                f"{where}: parameter {param!r} of {entry.cls.name!r} is "
                f"not sweepable — it selects a different compiled "
                f"program (sweepable: {sweepable or 'none'})")
        values = ax.get("values")
        if not isinstance(values, (list, tuple)) or not values:
            raise SweepError(f'{where}: "values" must be a non-empty '
                             f"list")
        bad = [v for v in values if not _is_jsonable(v)]
        if bad:
            raise SweepError(f"{where}: non-JSON value(s) {bad!r}")
        axes.append(SweepAxis(idx, param, tuple(values),
                              f"{entry.cls.name}.{param}"))
    seen = {(a.plugin_index, a.param) for a in axes}
    if len(seen) != len(axes):
        raise SweepError("sweep axes must name distinct (plugin, param) "
                         "pairs")
    return axes


def _resolve_entry(ax: dict, process_list: ProcessList, where: str
                   ) -> tuple[PluginEntry, int]:
    entries = process_list.entries
    idx = ax.get("plugin_index")
    if idx is not None:
        if not isinstance(idx, int) or isinstance(idx, bool) \
                or not 0 <= idx < len(entries):
            raise SweepError(
                f"{where}: plugin_index must be an int in "
                f"0..{len(entries) - 1}, got {idx!r}")
        return entries[idx], idx
    name = ax.get("plugin")
    if not isinstance(name, str):
        raise SweepError(f'{where}: needs "plugin_index" (int) or a '
                         f'"plugin" wire name')
    matches = [i for i, e in enumerate(entries) if e.cls.name == name]
    if len(matches) != 1:
        raise SweepError(
            f"{where}: plugin {name!r} matches {len(matches)} entries "
            f"(chain: {[e.cls.name for e in entries]}) — use "
            f'"plugin_index"')
    return entries[matches[0]], matches[0]


def expand_sweep(process_list: ProcessList, axes: Iterable[SweepAxis]
                 ) -> list[tuple[tuple, ProcessList]]:
    """Expand the grid: one (values, variant process list) per point, in
    C order (first axis outermost) — the order of the stacked result's
    leading dimension(s).  Every variant is a fresh ProcessList with
    copied params; chain signatures are identical by the tunable-param
    contract."""
    axes = list(axes)
    out: list[tuple[tuple, ProcessList]] = []
    for combo in itertools.product(*[a.values for a in axes]):
        pl = ProcessList()
        for i, e in enumerate(process_list.entries):
            params = dict(e.params)
            for a, v in zip(axes, combo):
                if a.plugin_index == i:
                    params[a.param] = v
            pl.add(e.cls, params=params, in_datasets=e.in_datasets,
                   out_datasets=e.out_datasets)
        out.append((combo, pl))
    return out


# ----------------------------------------------------------------------
# sweep groups
# ----------------------------------------------------------------------
@dataclasses.dataclass
class SweepGroup:
    """One submitted sweep: the expanded variant jobs plus group-level
    bookkeeping (grid shape, per-variant values, metric scores)."""

    sweep_id: str
    axes: list[SweepAxis]
    jobs: list[Job]
    values: list[tuple]                 # grid point per variant
    metric: str | None = None
    metadata: dict[str, Any] = dataclasses.field(default_factory=dict)
    created_at: float = dataclasses.field(default_factory=time.time)
    scores: list[float] | None = None   # filled lazily once all DONE
    score_error: str | None = None

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(len(a.values) for a in self.axes)

    @property
    def n_variants(self) -> int:
        return len(self.jobs)

    def all_terminal(self) -> bool:
        return all(j.state.terminal() for j in self.jobs)

    def state(self) -> str:
        """Aggregate state: ``queued`` (nothing started) / ``running`` /
        all-terminal ``done`` | ``cancelled`` | ``failed`` (any variant
        failed) | ``partial`` (mixed done+cancelled)."""
        states = {j.state.value for j in self.jobs}
        if not self.all_terminal():
            return "queued" if states == {"queued"} else "running"
        if states == {"done"}:
            return "done"
        if states == {"cancelled"}:
            return "cancelled"
        if "failed" in states:
            return "failed"
        return "partial"

    def best_variant(self) -> dict[str, Any] | None:
        if self.scores is None or not self.scores:
            return None
        m = METRICS[self.metric]
        pick = max if m.higher_is_better else min
        k = self.scores.index(pick(self.scores))
        return {"index": k, "job_id": self.jobs[k].job_id,
                "grid": [int(x) for x in np.unravel_index(k, self.shape)],
                "values": self.values_of(k), "score": self.scores[k]}

    def values_of(self, k: int) -> dict[str, Any]:
        return {a.label: v for a, v in zip(self.axes, self.values[k])}

    def snapshot(self, full: bool = True) -> dict[str, Any]:
        """JSON-able group view (``GET /sweeps/{id}``): aggregate state,
        grid shape + axes, per-variant snapshots with their grid values
        (and scores once computed), ``best_variant`` when a metric was
        requested and every variant is done."""
        counts: dict[str, int] = {}
        for j in self.jobs:
            counts[j.state.value] = counts.get(j.state.value, 0) + 1
        out: dict[str, Any] = {
            "sweep_id": self.sweep_id, "state": self.state(),
            "all_terminal": self.all_terminal(),
            "n_variants": self.n_variants, "shape": list(self.shape),
            "axes": [a.spec() for a in self.axes],
            "metric": self.metric, "created_at": self.created_at,
            "counts": counts,
            "metadata": {k: v for k, v in self.metadata.items()
                         if _is_jsonable(v)},
        }
        if self.score_error:
            out["score_error"] = self.score_error
        best = self.best_variant()
        if best is not None:
            out["best_variant"] = best
        if full:
            variants = []
            for k, j in enumerate(self.jobs):
                v = j.snapshot()
                v["sweep_values"] = self.values_of(k)
                if self.scores is not None:
                    v["score"] = self.scores[k]
                variants.append(v)
            out["variants"] = variants
        return out


# ----------------------------------------------------------------------
class SweepManager:
    """Expands sweep envelopes into atomically-admitted variant jobs and
    tracks them as :class:`SweepGroup`\\ s — the service-side owner of
    the ``/sweeps`` endpoints.

    Args:
        queue: the admission queue variants are submitted to.
        fetch: ``(job_id, dataset|None) -> np.ndarray`` resolver for a
            DONE variant's result (the service provides one that covers
            both in-process runners and broker-mode ``.npy`` spools) —
            used for metric scoring and result stacking.
        max_variants: bound on grid size (400 past it) — admission
            control (``max_pending``) applies on top.
        max_history: retained terminal groups; beyond it the oldest
            all-terminal groups are dropped (their variant jobs remain
            subject to the queue's own ``max_history``).
    """

    def __init__(self, queue: JobQueue, *,
                 fetch: Callable[[str, str | None], np.ndarray]
                 | None = None,
                 max_variants: int = 64,
                 max_history: int | None = 64):
        self.queue = queue
        self.fetch = fetch
        self.max_variants = max_variants
        self.max_history = max_history
        self._groups: dict[str, SweepGroup] = {}
        self._lock = threading.Lock()
        self._seq = itertools.count()
        self.sweeps_submitted = 0
        self.variants_submitted = 0

    # -- admission ------------------------------------------------------
    def submit(self, envelope: dict[str, Any]) -> SweepGroup:
        """Admit one sweep envelope::

            {"process_list": <spec v1 | ProcessList>,   # required
             "sweep": <axis | [axes]>,                  # required
             "metric": null, "priority": 0,
             "sweep_id": null, "metadata": {}}

        Expands the grid and submits every variant **atomically**
        (:meth:`JobQueue.submit_many`) — either the whole sweep is
        admitted (and can gang) or nothing is.

        Returns: the recorded :class:`SweepGroup`.
        Raises:
            SweepError / WireError / ProcessListError: invalid envelope
                or spec (HTTP 400).
            ValueError: duplicate active sweep/job id (HTTP 409).
            QueueFull: admission control rejected the whole group
                (HTTP 429).
        """
        if not isinstance(envelope, dict) or "process_list" not in envelope:
            raise SweepError('body must be an object with a '
                             '"process_list" spec')
        if "sweep" not in envelope:
            raise SweepError('body must carry a "sweep" block (use '
                             'POST /jobs for plain submissions)')
        priority = envelope.get("priority", 0)
        if not isinstance(priority, int) or isinstance(priority, bool):
            raise SweepError(f"priority must be an integer, got "
                             f"{priority!r}")
        metric = envelope.get("metric")
        if metric is not None and metric not in METRICS:
            raise SweepError(f"unknown metric {metric!r} "
                             f"(available: {sorted(METRICS)})")
        sweep_id = envelope.get("sweep_id")
        if sweep_id is not None and not isinstance(sweep_id, str):
            raise SweepError(f"sweep_id must be a string, got "
                             f"{sweep_id!r}")
        metadata = envelope.get("metadata") or {}
        if not isinstance(metadata, dict):
            raise SweepError("metadata must be an object")

        pl = envelope["process_list"]
        if not isinstance(pl, ProcessList):
            pl = from_spec(pl)
        pl.check()
        axes = parse_sweep_block(envelope["sweep"], pl)
        n = 1
        for a in axes:
            n *= len(a.values)
        if n > self.max_variants:
            raise SweepError(
                f"sweep expands to {n} variants "
                f"(max_variants={self.max_variants}) — coarsen the grid")
        variants = expand_sweep(pl, axes)

        with self._lock:
            self._prune_locked()
            if sweep_id is None:
                sweep_id = f"sweep-{next(self._seq):04d}"
            existing = self._groups.get(sweep_id)
            if existing is not None and not existing.all_terminal():
                raise ValueError(f"sweep id {sweep_id!r} already active")
        job_ids = [f"{sweep_id}/v{k:03d}" for k in range(len(variants))]
        metadatas = []
        for k, (combo, _) in enumerate(variants):
            md = dict(metadata)
            md["sweep"] = {
                "sweep_id": sweep_id, "index": k,
                "values": {a.label: v for a, v in zip(axes, combo)}}
            metadatas.append(md)
        jobs = self.queue.submit_many(
            [v for _, v in variants], priority=priority,
            job_ids=job_ids, metadatas=metadatas)
        group = SweepGroup(sweep_id, axes, jobs,
                           [combo for combo, _ in variants],
                           metric=metric, metadata=dict(metadata))
        with self._lock:
            self._groups[sweep_id] = group
            self.sweeps_submitted += 1
            self.variants_submitted += len(jobs)
        return group

    def _prune_locked(self) -> None:
        if self.max_history is None:
            return
        terminal = [g for g in self._groups.values() if g.all_terminal()]
        terminal.sort(key=lambda g: g.created_at)
        for g in terminal[:max(0, len(terminal) - self.max_history)]:
            del self._groups[g.sweep_id]

    # -- lookup ----------------------------------------------------------
    def group(self, sweep_id: str) -> SweepGroup:
        """Raises KeyError for an unknown (or pruned) sweep id."""
        with self._lock:
            return self._groups[sweep_id]

    def status(self, sweep_id: str, full: bool = True) -> dict[str, Any]:
        """The group snapshot, scoring variants first when a metric was
        requested and every variant is DONE (lazy, computed once)."""
        g = self.group(sweep_id)
        self._ensure_scores(g)
        return g.snapshot(full=full)

    def snapshot_all(self) -> list[dict[str, Any]]:
        """Summary snapshot of every retained group (``GET /sweeps``)."""
        with self._lock:
            groups = sorted(self._groups.values(),
                            key=lambda g: g.created_at)
        return [g.snapshot(full=False) for g in groups]

    # -- metric scoring ---------------------------------------------------
    def _ensure_scores(self, g: SweepGroup) -> None:
        if g.metric is None or g.scores is not None or self.fetch is None:
            return
        if g.state() != "done":
            return
        m = METRICS[g.metric]
        try:
            scores = [float(m.fn(self.fetch(j.job_id, None)))
                      for j in g.jobs]
        except (KeyError, RuntimeError, OSError) as e:
            # results evicted/unreadable: report, don't fail the status
            g.score_error = f"{type(e).__name__}: {e}"
            return
        g.scores = scores

    # -- cancellation -----------------------------------------------------
    def cancel(self, sweep_id: str,
               cancel_job: Callable[[str], dict[str, Any]]
               ) -> dict[str, Any]:
        """Cancel every live variant via ``cancel_job`` (the service's
        per-job cancel, which handles queued AND leased jobs).  Variants
        already terminal are left alone.  Raises KeyError if unknown."""
        g = self.group(sweep_id)
        cancelled, skipped = [], []
        for j in g.jobs:
            if j.state.terminal():
                skipped.append(j.job_id)
                continue
            try:
                out = cancel_job(j.job_id)
            except KeyError:          # evicted mid-loop
                skipped.append(j.job_id)
                continue
            (cancelled if out.get("cancelled") else skipped).append(
                j.job_id)
        return {"sweep_id": sweep_id, "state": g.state(),
                "cancelled": cancelled, "skipped": skipped}

    # -- results ----------------------------------------------------------
    def result_plan(self, sweep_id: str, dataset: str | None = None
                    ) -> tuple[SweepGroup, tuple[int, ...], np.dtype,
                               np.ndarray]:
        """Resolve what ``GET /sweeps/{id}/result`` will stream: the
        group, the STACKED shape (``(*grid_shape, *variant_shape)`` —
        the parameter axes lead, Savu's tuning dimension), the dtype,
        and the first variant's array (so the caller streams it without
        fetching twice).

        Raises:
            KeyError: unknown sweep.
            RuntimeError: not every variant is DONE (the message names
                the blocking states), or variant results disagree on
                shape/dtype (should not happen for identical chains).
        """
        g = self.group(sweep_id)
        if g.state() != "done":
            counts = {j.job_id: j.state.value for j in g.jobs
                      if j.state.value != "done"}
            raise RuntimeError(
                f"sweep {sweep_id!r} is {g.state()!r}, not done "
                f"(blocking: {counts})")
        if self.fetch is None:
            raise RuntimeError("no result fetcher configured")
        first = np.asarray(self.fetch(g.jobs[0].job_id, dataset))
        return (g, g.shape + first.shape, first.dtype, first)

    def stats(self) -> dict[str, Any]:
        """Counters for ``GET /stats``: groups retained/active plus
        lifetime ``sweeps_submitted`` / ``variants_submitted``."""
        with self._lock:
            groups = list(self._groups.values())
            out = {"sweeps_submitted": self.sweeps_submitted,
                   "variants_submitted": self.variants_submitted,
                   "groups": len(groups),
                   "active": sum(1 for g in groups
                                 if not g.all_terminal())}
        return out
