from .adamw import (AdamWConfig, adamw_update, clip_by_global_norm,
                    cosine_lr, global_norm, init_opt_state)

__all__ = ["AdamWConfig", "adamw_update", "init_opt_state", "cosine_lr",
           "global_norm", "clip_by_global_norm"]
