"""jit'd public wrapper for the backprojection kernel.

Chooses BlockSpec tiles with the paper's chunking optimiser (VMEM
budget), broadcasts over leading slice dims, and falls back to the
pure-jnp reference on hosts where Pallas-TPU is unavailable unless
interpret mode is forced.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ...core.chunking import optimise_block_shape
from ...core.patterns import Pattern
from .kernel import backproject_pallas
from .ref import backproject_ref


def _pick_blocks(out_size: int, n_angles: int, n_det: int
                 ) -> tuple[int, int, int]:
    """Tile choice via the §IV.A optimiser: treat the (H, W) image as a
    dataset whose now/next pattern slices rows, budget = VMEM, then round
    to hardware lanes.  The angle block is sized so the W tile (P × D)
    stays inside the budget."""
    img_pat = Pattern("BP_TILE", core_dims=(1,), slice_dims=(0,))
    bh, bw = optimise_block_shape((out_size, out_size), img_pat, None,
                                  itemsize=4, frames=8,
                                  vmem_bytes=2 * 1024 * 1024)
    bh = max(8, min(bh, 64))
    bw = min(bw, 256)
    while out_size % bh:
        bh //= 2
    while out_size % bw:
        bw //= 2
    # W tile is (bh*bw, n_det) fp32; keep it+sino under ~8MB
    ba = 16
    while ba > 1 and (bh * bw * n_det * 4 + ba * n_det * 4) > 8 * 2**20:
        ba //= 2
    while n_angles % ba:
        ba //= 2
    return max(1, bh), max(1, bw), max(1, ba)


@functools.partial(jax.jit, static_argnames=("out_size", "centre",
                                             "use_pallas", "interpret"))
def backproject(sino: jnp.ndarray, angles: jnp.ndarray, out_size: int,
                centre: float | None = None, *, use_pallas: bool = True,
                interpret: bool = True) -> jnp.ndarray:
    """Filtered-backproject sinogram(s) -> image(s).

    sino: (..., n_angles, n_det); returns (..., out_size, out_size).
    """
    sino = sino.astype(jnp.float32)
    lead = sino.shape[:-2]
    n_angles, n_det = sino.shape[-2:]
    flat = sino.reshape((-1, n_angles, n_det))

    if use_pallas:
        bh, bw, ba = _pick_blocks(out_size, n_angles, n_det)
        cos_t = jnp.cos(angles).astype(jnp.float32).reshape(-1, 1)
        sin_t = jnp.sin(angles).astype(jnp.float32).reshape(-1, 1)
        fn = lambda s: backproject_pallas(
            s, cos_t, sin_t, out_size=out_size, centre=centre,
            bh=bh, bw=bw, ba=ba, interpret=interpret)
    else:
        fn = lambda s: backproject_ref(s, angles, out_size, centre)
    out = jax.lax.map(fn, flat)
    return out.reshape(lead + (out_size, out_size))
