"""Fault-tolerant checkpointing: sharded save / restore / elastic
re-shard, with async double-buffering and retention.

Layout per step:  <dir>/step_<N>/
    manifest.json            tree structure, shapes, dtypes, step, extras
    leaf_<i>.npy             one file per pytree leaf (host-gathered)

Design notes for the 1000-node deployment (single-host container here):
  * each leaf is written by the process owning shard (0,0,…) —
    multi-host would write per-process shard files keyed by shard index;
    the manifest already records the PartitionSpec to make that split.
  * async: the save runs on a background thread over host copies, so the
    train loop is blocked only for the device->host transfer.
  * elastic restart: ``restore`` takes target shardings — a checkpoint
    written on a (16,16) mesh restores onto (2,16,16) (or 1 CPU device)
    by re-device_put'ing each leaf; shapes are mesh-independent because
    files always hold the GLOBAL array.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any

import numpy as np

import jax


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, *, extra: dict | None = None,
             blocking: bool = False) -> None:
        """Snapshot ``tree`` at ``step``.  Device->host happens here;
        file IO happens on a worker thread unless blocking."""
        self.wait()
        leaves, treedef = jax.tree.flatten(tree)
        host = [np.asarray(x) for x in leaves]      # gathers shards
        manifest = {
            "step": int(step),
            "treedef": str(treedef),
            "n_leaves": len(host),
            "shapes": [list(x.shape) for x in host],
            "dtypes": [str(x.dtype) for x in host],
            "extra": extra or {},
            "time": time.time(),
        }

        def write():
            tmp = os.path.join(self.dir, f".tmp_step_{step}")
            final = os.path.join(self.dir, f"step_{step}")
            os.makedirs(tmp, exist_ok=True)
            for i, arr in enumerate(host):
                np.save(os.path.join(tmp, f"leaf_{i}.npy"), arr)
            with open(os.path.join(tmp, "manifest.json"), "w") as fh:
                json.dump(manifest, fh)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)          # atomic publish
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self.steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                try:
                    out.append(int(name.split("_", 1)[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, *, step: int | None = None,
                shardings: Any = None) -> tuple[Any, dict]:
        """Restore into the structure of ``template``.  ``shardings``
        (optional pytree of NamedSharding) re-shards elastically onto the
        current mesh."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as fh:
            manifest = json.load(fh)
        leaves_t, treedef = jax.tree.flatten(template)
        if manifest["n_leaves"] != len(leaves_t):
            raise ValueError(
                f"checkpoint has {manifest['n_leaves']} leaves, template "
                f"has {len(leaves_t)} — incompatible trees")
        sh_leaves = (jax.tree.leaves(shardings)
                     if shardings is not None else [None] * len(leaves_t))
        out = []
        for i, (tmpl, sh) in enumerate(zip(leaves_t, sh_leaves)):
            arr = np.load(os.path.join(d, f"leaf_{i}.npy"))
            if tuple(arr.shape) != tuple(tmpl.shape):
                raise ValueError(
                    f"leaf {i}: checkpoint shape {arr.shape} != template "
                    f"{tmpl.shape}")
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jax.device_put(arr.astype(tmpl.dtype)))
        return jax.tree.unflatten(treedef, out), manifest
