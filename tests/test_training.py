"""Training-loop numerics: optimizer, schedules, microbatching,
gradient clipping — plus serving (generate / continuous batching)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models import ModelConfig, build_model
from repro.optim import (AdamWConfig, adamw_update, clip_by_global_norm,
                         cosine_lr, global_norm, init_opt_state)
from repro.training import (ContinuousBatcher, Request, greedy_generate,
                            init_training, make_serve_step, make_train_step)


def _tiny():
    cfg = ModelConfig(arch_id="t", family="dense", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab=64,
                      dtype=jnp.float32, remat=False)
    return build_model(cfg)


def test_loss_decreases_on_memorisation():
    model = _tiny()
    params, opt = init_training(model, jax.random.key(0))
    ts = jax.jit(make_train_step(
        model, AdamWConfig(lr=1e-2, warmup_steps=1, total_steps=100)))
    rng = np.random.default_rng(0)
    toks = rng.integers(0, 64, (4, 16)).astype(np.int32)
    batch = {"tokens": toks, "labels": toks}
    losses = []
    for _ in range(10):
        params, opt, m = ts(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.5


def test_microbatched_grads_match_full_batch():
    """Gradient accumulation must be loss-equivalent to the full batch."""
    model = _tiny()
    params, opt = init_training(model, jax.random.key(0))
    rng = np.random.default_rng(0)
    toks = rng.integers(0, 64, (8, 16)).astype(np.int32)
    batch = {"tokens": toks, "labels": toks}
    cfgo = AdamWConfig(lr=1e-3, warmup_steps=1)
    full = make_train_step(model, cfgo)
    micro = make_train_step(model, cfgo, microbatch=4)
    p1, _, m1 = jax.jit(full)(params, opt, batch)
    p2, _, m2 = jax.jit(micro)(params, opt, batch)
    # same loss (mean over same tokens) and near-identical update
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-5)


def test_cosine_schedule_shape():
    c = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                    min_lr_ratio=0.1)
    assert float(cosine_lr(c, jnp.asarray(0))) < 0.11
    assert abs(float(cosine_lr(c, jnp.asarray(10))) - 1.0) < 1e-6
    end = float(cosine_lr(c, jnp.asarray(100)))
    assert abs(end - 0.1) < 1e-6


def test_clip_by_global_norm():
    tree = {"a": jnp.ones((10,)) * 3.0, "b": jnp.ones((5,)) * 4.0}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) > 1.0
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    # under the limit -> untouched
    small = {"a": jnp.ones((2,)) * 1e-3}
    same, _ = clip_by_global_norm(small, 1.0)
    np.testing.assert_allclose(np.asarray(same["a"]),
                               np.asarray(small["a"]))


def test_adamw_weight_decay_pulls_to_zero():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.5, warmup_steps=1,
                      total_steps=10, clip_norm=1e9)
    params = {"w": jnp.ones((4,)) * 2.0}
    state = init_opt_state(params)
    grads = {"w": jnp.zeros((4,))}
    for _ in range(5):
        params, state, _ = adamw_update(cfg, params, grads, state)
    assert float(jnp.max(jnp.abs(params["w"]))) < 2.0


def test_greedy_generate_deterministic():
    model = _tiny()
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    toks = rng.integers(0, 64, (2, 8)).astype(np.int32)
    out1 = greedy_generate(model, params, {"tokens": toks}, max_new=5,
                           max_len=16)
    out2 = greedy_generate(model, params, {"tokens": toks}, max_new=5,
                           max_len=16)
    np.testing.assert_array_equal(out1, out2)
    assert out1.shape == (2, 5)


def test_continuous_batcher_completes_requests():
    model = _tiny()
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    batcher = ContinuousBatcher(model, params, slots=2, max_len=24)
    reqs = [Request(rid=i, prompt=rng.integers(0, 64, (6,)).astype(np.int32),
                    max_new=4) for i in range(5)]
    for r in reqs:
        batcher.submit(r)
    done = batcher.run()
    assert len(done) == 5
    assert all(len(r.generated) >= r.max_new for r in done)


def test_serve_step_roundtrip():
    model = _tiny()
    params = model.init(jax.random.key(0))
    cache = model.init_cache(2, 16)
    step = jax.jit(make_serve_step(model))
    tok = jnp.zeros((2, 1), jnp.int32)
    for _ in range(3):
        tok, cache = step(params, tok, cache)
    assert int(cache["length"]) == 3
    assert tok.shape == (2, 1)
