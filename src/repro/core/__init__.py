# The paper's primary contribution — a pattern-driven, plugin-based
# processing framework (Savu) re-expressed for JAX/TPU meshes.
from .patterns import (BATCH, DIFFRACTION, EXPERT, HEADS, PROJECTION,
                       SEQUENCE, SINOGRAM, SPECTRUM, TIMESERIES, TOKENS,
                       VOLUME_XZ, Pattern, pattern_from_labels)
from .dataset import DataSet
from .plugin import (BaseFilter, BaseLoader, BasePlugin, BaseRecon,
                     BaseSaver, CPU_DRIVER, GPU_DRIVER, LambdaFilter,
                     MeshDriver, PluginData)
from .process_list import PluginEntry, ProcessList, ProcessListError
from .framework import PluginRunner, run_process_list
from .transport import (ChunkedFile, ChunkedFileTransport, InMemoryTransport,
                        IOStats, ShardedTransport, Transport)
from .chunking import (DEFAULT_CACHE_BYTES, chunks_touched, naive_chunks,
                       optimise_block_shape, optimise_chunks)
from .profiler import Event, Profiler

__all__ = [
    "Pattern", "pattern_from_labels", "DataSet", "BasePlugin", "BaseFilter",
    "BaseRecon", "BaseLoader", "BaseSaver", "LambdaFilter", "MeshDriver",
    "PluginData", "CPU_DRIVER", "GPU_DRIVER", "ProcessList", "PluginEntry",
    "ProcessListError", "PluginRunner", "run_process_list", "Transport",
    "InMemoryTransport", "ShardedTransport", "ChunkedFileTransport",
    "ChunkedFile", "IOStats", "optimise_chunks", "optimise_block_shape",
    "naive_chunks", "chunks_touched", "DEFAULT_CACHE_BYTES", "Profiler",
    "Event", "PROJECTION", "SINOGRAM", "SPECTRUM", "DIFFRACTION",
    "VOLUME_XZ", "TIMESERIES", "BATCH", "SEQUENCE", "TOKENS", "EXPERT",
    "HEADS",
]
