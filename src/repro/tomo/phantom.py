"""Synthetic data: Shepp–Logan-style phantoms + a differentiable
parallel-beam forward projector (Radon transform).

These are the data-generation oracle for the whole tomography test
suite: phantom → forward project → (simulated dark/flat/noise) → the
Savu chain must reconstruct something close to the phantom.
"""
from __future__ import annotations

import functools
import math

import numpy as np

import jax
import jax.numpy as jnp

from .geometry import ParallelGeometry

# (value, a, b, x0, y0, phi_deg) — standard Shepp-Logan ellipses
# (modified/high-contrast variant so tests have healthy SNR).
_SHEPP_LOGAN = [
    (1.00, 0.69, 0.92, 0.0, 0.0, 0),
    (-0.80, 0.6624, 0.8740, 0.0, -0.0184, 0),
    (-0.20, 0.1100, 0.3100, 0.22, 0.0, -18),
    (-0.20, 0.1600, 0.4100, -0.22, 0.0, 18),
    (0.10, 0.2100, 0.2500, 0.0, 0.35, 0),
    (0.10, 0.0460, 0.0460, 0.0, 0.10, 0),
    (0.10, 0.0460, 0.0460, 0.0, -0.10, 0),
    (0.10, 0.0460, 0.0230, -0.08, -0.605, 0),
    (0.10, 0.0230, 0.0230, 0.0, -0.606, 0),
    (0.10, 0.0230, 0.0460, 0.06, -0.605, 0),
]


def shepp_logan(n: int, dtype=np.float32) -> np.ndarray:
    """n×n modified Shepp–Logan phantom in [0, ~1]."""
    ys, xs = np.mgrid[-1:1:n * 1j, -1:1:n * 1j]
    img = np.zeros((n, n), dtype=np.float64)
    for val, a, b, x0, y0, phi in _SHEPP_LOGAN:
        th = math.radians(phi)
        c, s = math.cos(th), math.sin(th)
        xr = (xs - x0) * c + (ys - y0) * s
        yr = -(xs - x0) * s + (ys - y0) * c
        img[(xr / a) ** 2 + (yr / b) ** 2 <= 1.0] += val
    return img.astype(dtype)


def phantom_stack(n: int, n_rows: int, dtype=np.float32) -> np.ndarray:
    """(n_rows, n, n) phantom volume: Shepp–Logan modulated per row, so
    adjacent slices differ (tests catch axis mix-ups)."""
    base = shepp_logan(n, np.float64)
    rows = []
    for r in range(n_rows):
        scale = 0.5 + 0.5 * (r + 1) / n_rows
        rows.append(base * scale)
    return np.stack(rows).astype(dtype)


# ----------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("n_angles", "n_det"))
def _project_slice(img: jnp.ndarray, angles: jnp.ndarray, n_angles: int,
                   n_det: int) -> jnp.ndarray:
    """Radon transform of one (H, W) slice -> (n_angles, n_det) sinogram.

    Rotation-based: for each angle rotate the image by -θ with bilinear
    sampling and integrate columns.  Differentiable; matches FBP's
    adjoint conventions (t = x·cosθ + y·sinθ with pixel units)."""
    h, w = img.shape
    cy, cx = (h - 1) / 2.0, (w - 1) / 2.0
    cd = (n_det - 1) / 2.0
    # sample grid in detector coords: t along detector, s along the ray
    n_s = h  # integration samples
    t = jnp.arange(n_det, dtype=img.dtype) - cd
    s = jnp.arange(n_s, dtype=img.dtype) - (n_s - 1) / 2.0

    def one_angle(theta):
        ct, st = jnp.cos(theta), jnp.sin(theta)
        # point = t*(cos,sin) + s*(-sin,cos) in (x, y)
        xs = t[None, :] * ct - s[:, None] * st + cx
        ys = t[None, :] * st + s[:, None] * ct + cy
        x0 = jnp.floor(xs)
        y0 = jnp.floor(ys)
        fx = xs - x0
        fy = ys - y0
        x0i = jnp.clip(x0.astype(jnp.int32), 0, w - 1)
        x1i = jnp.clip(x0i + 1, 0, w - 1)
        y0i = jnp.clip(y0.astype(jnp.int32), 0, h - 1)
        y1i = jnp.clip(y0i + 1, 0, h - 1)
        inside = ((xs >= 0) & (xs <= w - 1) & (ys >= 0) & (ys <= h - 1))
        v = (img[y0i, x0i] * (1 - fx) * (1 - fy) +
             img[y0i, x1i] * fx * (1 - fy) +
             img[y1i, x0i] * (1 - fx) * fy +
             img[y1i, x1i] * fx * fy)
        return jnp.sum(jnp.where(inside, v, 0.0), axis=0)

    return jax.vmap(one_angle)(angles.astype(img.dtype))


def forward_project(volume: np.ndarray, geom: ParallelGeometry
                    ) -> np.ndarray:
    """(rows, H, W) volume -> (n_angles, rows, n_det) projection data
    in the paper's (θ, y, x) layout."""
    vol = jnp.asarray(volume)
    if vol.ndim == 2:
        vol = vol[None]
    angles = jnp.asarray(geom.angles)
    sinos = jax.vmap(lambda s: _project_slice(
        s, angles, geom.n_angles, geom.n_det))(vol)  # (rows, ang, det)
    return np.asarray(jnp.transpose(sinos, (1, 0, 2)))


def simulate_raw_scan(volume: np.ndarray, geom: ParallelGeometry, *,
                      i0: float = 40000.0, dark_level: float = 96.0,
                      noise: float = 0.0, seed: int = 0,
                      mu: float = 0.02) -> dict[str, np.ndarray]:
    """Make a realistic uint16 raw scan from a phantom volume:
    transmission I = dark + (I0-dark)·exp(-μ·path) with optional Poisson
    noise; plus dark/flat fields — i.e. what a loader plugin would see."""
    proj = forward_project(volume, geom)           # path lengths (θ, y, x)
    rng = np.random.default_rng(seed)
    flat = np.full(proj.shape[1:], i0, dtype=np.float64)
    flat += rng.normal(0, i0 * 0.002, size=flat.shape)
    dark = np.full(proj.shape[1:], dark_level, dtype=np.float64)
    trans = np.exp(-mu * proj.astype(np.float64))
    counts = dark[None] + (flat[None] - dark[None]) * trans
    if noise > 0:
        counts = rng.poisson(np.clip(counts / noise, 0, None)) * noise
    return {
        "data": np.clip(counts, 0, 65535).astype(np.uint16),
        "dark": np.clip(dark, 0, 65535).astype(np.uint16),
        "flat": np.clip(flat, 0, 65535).astype(np.uint16),
        "mu": mu,
        "truth": np.asarray(volume, dtype=np.float32),
    }
