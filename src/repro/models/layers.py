"""Shared layers: norms, rotary embeddings, token embedding, dense."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ModelConfig, dense_init
from .sharding import get_rules


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
               eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * scale + bias


# ----------------------------------------------------------------------
def rope_freqs(head_dim: int, fraction: float, theta: float,
               positions: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """cos/sin tables (…, rot_dim/2) for given positions (any shape)."""
    rot = int(head_dim * fraction) // 2 * 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray
               ) -> jnp.ndarray:
    """x (..., S, H, D); cos/sin (..., S, rot/2) -> rotated x.

    Partial rotary: only the first ``2*cos.shape[-1]`` dims rotate
    (chatglm-style 2-d / half rope), the rest pass through.
    """
    rot = 2 * cos.shape[-1]
    xr, xp = x[..., :rot], x[..., rot:]
    x1 = xr[..., 0::2]
    x2 = xr[..., 1::2]
    # broadcast cos/sin over the head axis: (..., S, 1, rot/2)
    c = cos[..., :, None, :].astype(jnp.float32)
    s = sin[..., :, None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    o1 = x1f * c - x2f * s
    o2 = x2f * c + x1f * s
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape).astype(x.dtype)
    return jnp.concatenate([out, xp], axis=-1) if xp.shape[-1] else out


# ----------------------------------------------------------------------
def init_embedding(key, cfg: ModelConfig):
    return dense_init(key, cfg.d_model, (cfg.vocab, cfg.d_model),
                      cfg.param_dtype)


def embed_tokens(table: jnp.ndarray, tokens: jnp.ndarray, dtype
                 ) -> jnp.ndarray:
    r = get_rules()
    out = jnp.take(table.astype(dtype), tokens, axis=0)
    return r.constrain(out, "batch", "seq", "embed_act")


def unembed(table: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """(B, S, d) -> (B, S, vocab) logits, fp32."""
    r = get_rules()
    logits = jnp.einsum("bsd,vd->bsv", x.astype(jnp.float32),
                        table.astype(jnp.float32))
    return r.constrain(logits, "batch", "seq", "vocab_act")
