"""JobQueue — priority admission queue for pipeline jobs.

Higher ``priority`` pops first; equal priorities are FIFO.  Admission
control bounds the number of non-terminal jobs in the system
(``max_pending``): past the bound, ``submit`` either raises
:class:`QueueFull` (caller sheds load) or, with ``block=True``, applies
backpressure by waiting for capacity.  ``get_batch`` pops the head job
plus queued jobs with the SAME chain signature so the scheduler can gang
them into one compiled call per plugin step.
"""
from __future__ import annotations

import heapq
import itertools
import threading
import time
from typing import Any, Callable

from ..core.process_list import ProcessList
from .job import Job, JobState


class QueueFull(RuntimeError):
    """Admission control rejected the submission (queue at max_pending)."""


class JobQueue:
    """Priority admission queue — the service side of the paper's
    "simultaneous processing of multiple datasets" (§I): many users'
    process lists queued against one facility pipeline.  Thread-safe;
    shared between HTTP handler threads and scheduler workers."""

    def __init__(self, max_pending: int | None = None,
                 max_history: int | None = None):
        """Args:
            max_pending: bound on non-terminal jobs; ``submit`` past it
                raises :class:`QueueFull` (or blocks with ``block=True``).
                None = unbounded.
            max_history: bound on retained TERMINAL jobs: beyond it the
                oldest finished jobs are evicted (their runner —
                datasets, device buffers, transport — released with
                them).  None keeps everything, which is right for batch
                CLIs/tests that read results after drain but leaks in a
                long-lived service.
        """
        self.max_pending = max_pending
        self.max_history = max_history
        self._heap: list[tuple[int, int, Job]] = []
        self._jobs: dict[str, Job] = {}
        self._lock = threading.Lock()
        self._not_empty = threading.Condition(self._lock)
        self._capacity = threading.Condition(self._lock)
        self._seq = itertools.count()
        self._evict_hooks: list[Callable[[Job], None]] = []

    def add_evict_hook(self, hook: Callable[[Job], None]) -> None:
        """Register a callback fired for each TERMINAL job evicted by
        ``max_history`` pruning — how the broker ties its result spool
        GC to job retention.  Called with the evicted Job *after* it is
        removed and *outside* the queue lock (hooks may do filesystem
        I/O); exceptions are swallowed."""
        self._evict_hooks.append(hook)

    def _fire_evict_hooks(self, evicted: list[Job]) -> None:
        for job in evicted:
            for hook in self._evict_hooks:
                try:
                    hook(job)
                except Exception:    # noqa: BLE001 — GC best-effort
                    pass

    # -- admission ------------------------------------------------------
    def _pending_locked(self) -> int:
        return sum(1 for j in self._jobs.values() if not j.state.terminal())

    def _prune_locked(self) -> list[Job]:
        """Evict over-history terminal jobs; returns them so the caller
        can fire the evict hooks once the lock is released."""
        if self.max_history is None:
            return []
        terminal = sorted((j for j in self._jobs.values()
                           if j.state.terminal()), key=lambda j: j.seq)
        evicted = terminal[:max(0, len(terminal) - self.max_history)]
        for j in evicted:
            j.runner = None
            del self._jobs[j.job_id]
        return evicted

    def submit(self, process_list: ProcessList, *, priority: int = 0,
               job_id: str | None = None, block: bool = False,
               timeout: float | None = None,
               metadata: dict[str, Any] | None = None,
               trace_id: str | None = None) -> Job:
        """Admit one process list as a :class:`Job`.

        Args:
            process_list: the chain to run (checked at dispatch, not
                here — use ``ProcessList.check()`` first to fail fast).
            priority: higher pops first; FIFO within a priority.
            job_id: explicit id (resubmit a killed job's id to resume
                from its checkpoint); default ``job-{seq:04d}``.
            block: past ``max_pending``, wait for capacity instead of
                raising.
            timeout: cap on the ``block=True`` wait, in seconds.
            metadata: free-form annotations carried on the job.
            trace_id: explicit telemetry trace id (correlate with an
                external tracer); default a fresh one per job.

        Returns: the QUEUED job.
        Raises:
            QueueFull: admission rejected (or the blocking wait timed
                out).
            ValueError: ``job_id`` names a still-active job.
        """
        def check_id():
            # re-checked after every capacity wait: two blocked
            # submitters with the same explicit id must not both insert
            if (job_id in self._jobs
                    and not self._jobs[job_id].state.terminal()):
                raise ValueError(f"job id {job_id!r} already active")

        evicted: list[Job] = []
        try:
            with self._lock:
                evicted = self._prune_locked()
                seq = next(self._seq)
                job_id = job_id or f"job-{seq:04d}"
                check_id()
                if self.max_pending is not None:
                    deadline = (None if timeout is None
                                else time.time() + timeout)
                    while self._pending_locked() >= self.max_pending:
                        if not block:
                            raise QueueFull(
                                f"{self._pending_locked()} jobs pending "
                                f"(max_pending={self.max_pending})")
                        remaining = (None if deadline is None
                                     else deadline - time.time())
                        if remaining is not None and remaining <= 0:
                            raise QueueFull(
                                f"timed out after {timeout}s waiting for "
                                f"queue capacity")
                        self._capacity.wait(remaining)
                        check_id()
                job = Job(job_id, process_list, priority=priority, seq=seq,
                          metadata=dict(metadata or {}),
                          trace_id=trace_id or "")
                self._jobs[job_id] = job
                heapq.heappush(self._heap, (-priority, seq, job))
                self._not_empty.notify()
                return job
        finally:
            # hooks (broker spool GC) do filesystem I/O — never under
            # the queue lock, and even when admission raises
            self._fire_evict_hooks(evicted)

    def submit_many(self, process_lists: list[ProcessList], *,
                    priority: int = 0,
                    job_ids: list[str] | None = None,
                    metadatas: list[dict[str, Any]] | None = None
                    ) -> list[Job]:
        """Admit a GROUP of process lists atomically — all admitted, or
        nothing is.  The jobs get consecutive ``seq`` numbers under one
        lock hold, so no other submission (or dispatch) interleaves: a
        gang-batching pop sees the whole group together.  This is the
        parameter-sweep admission path (``repro.service.sweep``).

        Args:
            process_lists: the chains, in variant order.
            priority: shared by every member (a sweep is one workload).
            job_ids: explicit ids, same length (default ``job-{seq}``).
            metadatas: per-job annotations, same length.

        Returns: the queued Jobs, in input order.
        Raises:
            QueueFull: the WHOLE group would exceed ``max_pending`` —
                nothing was admitted.
            ValueError: a job id is already active (or duplicated within
                the group) — nothing was admitted.
        """
        n = len(process_lists)
        if job_ids is not None and len(job_ids) != n:
            raise ValueError(f"{len(job_ids)} job_ids for {n} jobs")
        if metadatas is not None and len(metadatas) != n:
            raise ValueError(f"{len(metadatas)} metadatas for {n} jobs")
        evicted: list[Job] = []
        try:
            with self._lock:
                evicted = self._prune_locked()
                if self.max_pending is not None and \
                        self._pending_locked() + n > self.max_pending:
                    raise QueueFull(
                        f"group of {n} would exceed max_pending="
                        f"{self.max_pending} ({self._pending_locked()} "
                        f"already pending)")
                if job_ids is not None:
                    if len(set(job_ids)) != n:
                        raise ValueError(
                            "duplicate job ids within the group")
                    for jid in job_ids:
                        if jid in self._jobs and \
                                not self._jobs[jid].state.terminal():
                            raise ValueError(
                                f"job id {jid!r} already active")
                jobs = []
                for i, pl in enumerate(process_lists):
                    seq = next(self._seq)
                    jid = job_ids[i] if job_ids is not None \
                        else f"job-{seq:04d}"
                    job = Job(jid, pl, priority=priority, seq=seq,
                              metadata=dict((metadatas or [{}] * n)[i]))
                    self._jobs[jid] = job
                    heapq.heappush(self._heap, (-priority, seq, job))
                    jobs.append(job)
                self._not_empty.notify_all()
                return jobs
        finally:
            self._fire_evict_hooks(evicted)

    # -- dispatch -------------------------------------------------------
    def _pop_locked(self, predicate: Callable[[Job], bool] | None = None
                    ) -> Job | None:
        # Eligibility-filtered pop: scan the FULL dispatch order
        # (-priority, seq) and take the first eligible queued job —
        # matching the capability ``predicate`` AND, for streaming jobs,
        # with work available (:meth:`Job.stream_ready`: a frame-starved
        # streaming job keeps its queue position without burning a
        # dispatch slot or lease until frames/EOF arrive and ``kick()``
        # re-wakes the waiters).  Non-eligible QUEUED jobs are left
        # exactly where they are: an unmatchable high-priority head
        # never shadows a matchable lower-priority job (we keep scanning
        # past it), and because skipped entries are not popped/re-pushed
        # their position — and FIFO fairness — is preserved for the
        # worker that CAN run them.  Terminal tombstones (cancelled
        # while queued) are discarded as the scan passes them.
        taken = None
        dead: list[tuple] = []
        for entry in sorted(self._heap, key=lambda e: (e[0], e[1])):
            job = entry[2]
            if job.state is not JobState.QUEUED:
                dead.append(entry)
                continue
            if job.stream_ready() and (predicate is None
                                       or predicate(job)):
                job.state = JobState.CHECKING
                taken = entry
                break
        if taken is not None:
            dead.append(taken)
        if dead:
            drop = {id(e) for e in dead}
            self._heap = [e for e in self._heap if id(e) not in drop]
            heapq.heapify(self._heap)
        return None if taken is None else taken[2]

    def kick(self) -> None:
        """Wake every blocked :meth:`get`/:meth:`get_batch` caller so it
        re-evaluates job eligibility — called by the ingest endpoints
        when frames or EOF arrive for a parked streaming job (its
        ``stream_ready()`` may just have flipped to True)."""
        with self._lock:
            self._not_empty.notify_all()

    def get(self, timeout: float | None = None,
            predicate: Callable[[Job], bool] | None = None) -> Job | None:
        """Pop the highest-priority queued job (None on timeout).

        Args:
            timeout: seconds to wait for a (matching) job; None = forever.
            predicate: capability filter — only jobs it accepts are
                eligible; non-matching jobs keep their queue position
                (see :meth:`_pop_locked` for the starvation guarantee).
        """
        deadline = None if timeout is None else time.time() + timeout
        with self._lock:
            while True:
                job = self._pop_locked(predicate)
                if job is not None:
                    return job
                remaining = (None if deadline is None
                             else deadline - time.time())
                if remaining is not None and remaining <= 0:
                    return None
                self._not_empty.wait(remaining)

    def get_batch(self, max_jobs: int, timeout: float | None = None,
                  match: Callable[[Job, Job], bool] | None = None,
                  predicate: Callable[[Job], bool] | None = None
                  ) -> list[Job]:
        """Pop the head job plus up to ``max_jobs - 1`` queued jobs with
        an identical chain signature (gang scheduling).  Candidates are
        scanned in dispatch order — sorted ``(-priority, seq)``, not raw
        heap-array order — so gang members join by priority then FIFO
        and a truncated gang takes the jobs whose turn it actually is.
        ``predicate`` restricts both the head and the gang members to
        jobs a capability-filtered worker can run (lease path).
        Streaming jobs never gang — their pace is set by frame arrival,
        not by the compiled step loop — so a streaming head pops solo
        and streaming members are skipped."""
        head = self.get(timeout, predicate)
        if head is None:
            return []
        if head.streaming:
            return [head]
        match = match or (lambda a, b: a.chain_sig == b.chain_sig)
        batch = [head]
        with self._lock:
            for entry in sorted(self._heap, key=lambda e: (e[0], e[1])):
                if len(batch) >= max_jobs:
                    break
                job = entry[2]
                if job.state is JobState.QUEUED and not job.streaming \
                        and match(head, job) \
                        and (predicate is None or predicate(job)):
                    job.state = JobState.CHECKING
                    batch.append(job)
            if len(batch) > 1:
                taken = {id(j) for j in batch}
                self._heap = [e for e in self._heap
                              if id(e[2]) not in taken]
                heapq.heapify(self._heap)
        return batch

    def requeue(self, job: Job) -> bool:
        """Put a dispatched (leased) job back in the queue — the broker's
        lease-expiry path.  The job keeps its original ``seq``, so it
        re-enters at the FRONT of its priority class (it is the oldest
        submission there) and resumes promptly on the next capable
        worker.  Returns False (and does nothing) for terminal jobs."""
        with self._lock:
            if job.state.terminal() or job.state is JobState.QUEUED:
                return False
            job.state = JobState.QUEUED
            job.requeued_at = time.time()
            heapq.heappush(self._heap, (-job.priority, job.seq, job))
            self._not_empty.notify()
            return True

    # -- bookkeeping ----------------------------------------------------
    def job(self, job_id: str) -> Job:
        """Look up a job by id.  Raises KeyError if unknown (or already
        evicted by ``max_history``)."""
        with self._lock:
            return self._jobs[job_id]

    def cancel(self, job_id: str) -> bool:
        """Cancel a job that has not been dispatched yet.

        Returns:
            True — the job was QUEUED and is now CANCELLED (terminal;
            it will never execute, and blocked submitters are woken).
            False — unknown id, already dispatched (a worker owns it),
            or already terminal.  The refusal never mutates the job, so
            a cancel racing a dispatch resolves to exactly one winner.
        """
        with self._lock:
            job = self._jobs.get(job_id)
            if job is None or job.state is not JobState.QUEUED:
                return False
            job.state = JobState.CANCELLED
            job.finished_at = time.time()
            self._capacity.notify_all()
            return True

    def notify_terminal(self) -> None:
        """Scheduler hook: a job reached a terminal state — wake blocked
        submitters (admission capacity freed)."""
        with self._lock:
            self._capacity.notify_all()

    def pending(self) -> int:
        """Number of non-terminal jobs (what admission control counts)."""
        with self._lock:
            return self._pending_locked()

    def queue_info(self) -> dict[str, Any]:
        """Starvation visibility (``GET /stats`` ``queue`` block): depth
        of still-QUEUED jobs, per-priority breakdown, and the oldest
        queued job's id + age since submission — the number that grows
        when the service is overloaded or a job is unmatchable."""
        now = time.time()
        with self._lock:
            queued = [j for j in self._jobs.values()
                      if j.state is JobState.QUEUED]
            by_priority: dict[str, int] = {}
            for j in queued:
                key = str(j.priority)
                by_priority[key] = by_priority.get(key, 0) + 1
            oldest = min(queued, key=lambda j: j.submitted_at,
                         default=None)
            return {
                "depth": len(queued),
                "by_priority": by_priority,
                "oldest_pending_job": (None if oldest is None
                                       else oldest.job_id),
                "oldest_pending_age": (None if oldest is None else
                                       round(now - oldest.submitted_at,
                                             6)),
            }

    def snapshot(self) -> list[dict[str, Any]]:
        """Every retained job's ``Job.snapshot()``, submission-ordered
        (``GET /jobs``)."""
        with self._lock:
            return [j.snapshot() for j in
                    sorted(self._jobs.values(), key=lambda j: j.seq)]

    def wait_all(self, timeout: float | None = None,
                 poll: float = 0.02) -> bool:
        """Block until every submitted job is terminal.  True on success,
        False on timeout."""
        deadline = None if timeout is None else time.time() + timeout
        while True:
            with self._lock:
                if all(j.state.terminal() for j in self._jobs.values()):
                    return True
            if deadline is not None and time.time() >= deadline:
                return False
            time.sleep(poll)
