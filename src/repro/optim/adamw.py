"""Hand-rolled sharded AdamW (+ global-norm clip, cosine schedule).

No optax in the container, and none needed: the optimizer is a pure
pytree map, so the moments inherit the parameter shardings (ZeRO-style)
for free — each device updates exactly the parameter shard it owns.
Moments are fp32 regardless of parameter dtype.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    #: 'fp32' or 'int8' — blockwise-quantised moments (Dettmers-style
    #: 8-bit Adam): 4+4 bytes/param -> ~2.06; what lets a 400B MoE train
    #: on a single 256-chip v5e pod (see EXPERIMENTS.md §Perf).
    moments_dtype: str = "fp32"


def _q8(x: jnp.ndarray) -> dict:
    """fp32 -> *dynamic* int8 (quadratic map, bnb-style):

        deq = sign(q) · (|q|/127)² · rowmax

    The quadratic code allocates resolution near zero — linear int8
    zeroes small second-moment entries and Adam then divides by ~eps,
    which diverges (measured; see EXPERIMENTS.md §Perf).

    STRUCTURAL: q keeps the parameter's own shape (scales along the
    last dim), so q inherits the parameter sharding unchanged.  A
    flat (nblocks, 256) layout reshapes across shard boundaries and
    XLA "involuntarily rematerialises" (replicates!) the dequantised
    fp32 moments — measured at +900 GiB/device on the 235B MoE."""
    if x.ndim == 0:
        x = x.reshape(1)
    s = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True), 1e-20)
    norm = jnp.clip(jnp.abs(x) / s, 0.0, 1.0)
    q = jnp.round(jnp.sqrt(norm) * 127.0) * jnp.sign(x)
    return {"q": q.astype(jnp.int8), "s": s}


def _dq8(d: dict, shape: tuple[int, ...]) -> jnp.ndarray:
    qf = d["q"].astype(jnp.float32)
    out = jnp.sign(qf) * (jnp.abs(qf) / 127.0) ** 2 * d["s"]
    return out.reshape(shape)


def init_opt_state(params: Any, moments_dtype: str = "fp32") -> dict:
    if moments_dtype == "int8":
        zq = lambda p: _q8(jnp.zeros(p.shape, jnp.float32))
        is_leaf = None
        return {
            "m": jax.tree.map(zq, params),
            "v": jax.tree.map(zq, params),
            "step": jnp.zeros((), jnp.int32),
        }
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def cosine_lr(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, step / max(1, cfg.warmup_steps))
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(math.pi * prog))
    scale = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * scale


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree: Any, max_norm: float
                        ) -> tuple[Any, jnp.ndarray]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), tree), norm


def adamw_update(cfg: AdamWConfig, params: Any, grads: Any,
                 state: dict) -> tuple[Any, dict, dict]:
    """One AdamW step.  Returns (params, state, metrics)."""
    step = state["step"] + 1
    lr = cosine_lr(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    int8 = cfg.moments_dtype == "int8"

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        if int8:
            m = _dq8(m, p.shape)
            v = jnp.maximum(_dq8(v, p.shape), 0.0)
        m_new = b1 * m + (1 - b1) * gf
        v_new = b2 * v + (1 - b2) * gf * gf
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        if int8:
            return p_new, _q8(m_new), _q8(v_new)
        return p_new, m_new, v_new

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    mdef_leaf = (lambda x: isinstance(x, dict) and set(x) == {"q", "s"}) \
        if int8 else None
    flat_m = jax.tree.leaves(state["m"], is_leaf=mdef_leaf)
    flat_v = jax.tree.leaves(state["v"], is_leaf=mdef_leaf)
    outs = [upd(p, g, m, v) for p, g, m, v in
            zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in outs])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in outs])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in outs])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"lr": lr, "grad_norm": gnorm}
