"""LM data pipeline as Savu loader plugins.

The training data path is expressed in the paper's own vocabulary: a
*loader* plugin creates a lazily-backed token DataSet with a BATCH
pattern (slice dim = sample -> `data` axis); the batcher slices frames
of ``global_batch`` samples.  Restart safety comes from determinism:
the stream is a pure function of (seed, step), so resuming from a
checkpointed step replays the identical remaining stream with no
cursor state to persist.
"""
from __future__ import annotations

import numpy as np

from ..core.dataset import DataSet
from ..core.patterns import BATCH
from ..core.plugin import BaseLoader


def token_stream(vocab: int, batch: int, seq: int, *, seed: int,
                 step: int) -> dict[str, np.ndarray]:
    """Deterministic synthetic LM batch for (seed, step)."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    toks = rng.integers(0, vocab, (batch, seq)).astype(np.int32)
    labels = np.concatenate([toks[:, 1:],
                             np.full((batch, 1), -1, np.int32)], axis=1)
    return {"tokens": toks, "labels": labels}


class SyntheticTokenLoader(BaseLoader):
    """Loader plugin: a (samples, seq) token dataset with BATCH pattern."""

    name = "synthetic_token_loader"
    parameters = {"vocab": 1024, "samples": 64, "seq": 128, "seed": 0}

    def load(self) -> list[DataSet]:
        p = self.params
        rng = np.random.default_rng(p["seed"])

        def thunk():
            return rng.integers(0, p["vocab"],
                                (p["samples"], p["seq"])).astype(np.int32)

        ds = DataSet(self.out_dataset_names[0],
                     (p["samples"], p["seq"]), np.int32,
                     ("sample", "token"), backing=thunk)
        ds.add_pattern(BATCH, core=("token",), slice_=("sample",))
        ds.metadata["vocab"] = p["vocab"]
        return [ds]


class TokenBatcher:
    """Iterates BATCH-pattern frames of ``global_batch`` samples from a
    token DataSet — the framework-native epoch loop."""

    def __init__(self, dataset: DataSet, global_batch: int):
        self.ds = dataset
        self.gb = global_batch
        self.pattern = dataset.get_pattern(BATCH)

    def __iter__(self):
        data = np.asarray(self.ds.materialise())
        frames = self.pattern.to_frames(data)
        for start in range(0, frames.shape[0] - self.gb + 1, self.gb):
            toks = frames[start:start + self.gb]
            labels = np.concatenate(
                [toks[:, 1:], np.full((self.gb, 1), -1, np.int32)], axis=1)
            yield {"tokens": toks, "labels": labels}
