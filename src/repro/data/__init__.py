from .pipeline import SyntheticTokenLoader, TokenBatcher, token_stream

__all__ = ["SyntheticTokenLoader", "TokenBatcher", "token_stream"]
