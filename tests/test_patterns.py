"""Pattern semantics: the core Savu abstraction."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import DataSet, Pattern
from repro.core.patterns import pattern_from_labels


def test_pattern_validation():
    with pytest.raises(ValueError):
        Pattern("X", core_dims=(0, 1), slice_dims=(1,))   # overlap
    with pytest.raises(ValueError):
        Pattern("X", core_dims=(0,), slice_dims=(2,))     # gap
    p = Pattern("OK", core_dims=(1, 2), slice_dims=(0,))
    assert p.ndim == 3
    assert p.dim_type(0) == "slice"
    assert p.dim_type(1) == "core"


def test_dim_types_first_slice_vs_other():
    p = Pattern("P", core_dims=(2, 3), slice_dims=(0, 1))
    assert p.dim_type(0) == "slice"     # first slice dim
    assert p.dim_type(1) == "other"


def test_frame_shape_and_count():
    p = Pattern("SINOGRAM", core_dims=(0, 2), slice_dims=(1,))
    assert p.frame_shape((8, 6, 4)) == (8, 4)
    assert p.n_frames((8, 6, 4)) == 6


@given(shape=st.tuples(st.integers(1, 5), st.integers(1, 5),
                       st.integers(1, 5), st.integers(1, 4)))
@settings(max_examples=25, deadline=None)
def test_to_from_frames_roundtrip_4d(shape):
    """Property: to_frames → from_frames is the identity for any pattern."""
    a = np.arange(np.prod(shape)).reshape(shape)
    for core, slc in [((1, 2), (0, 3)), ((0, 3), (2, 1)), ((2,), (0, 1, 3))]:
        p = Pattern("P", core_dims=core, slice_dims=slc)
        f = p.to_frames(a)
        assert f.shape == (p.n_frames(shape),) + p.frame_shape(shape)
        back = p.from_frames(f, shape)
        np.testing.assert_array_equal(back, a)


def test_frame_slices_cover_everything_once():
    p = Pattern("P", core_dims=(1,), slice_dims=(0, 2))
    shape = (5, 3, 4)
    seen = np.zeros(shape, dtype=int)
    for idx in p.frame_slices(shape, m=2):
        seen[idx] += 1
    np.testing.assert_array_equal(seen, np.ones(shape, int))


def test_frame_slices_first_slice_dim_fastest():
    p = Pattern("P", core_dims=(2,), slice_dims=(0, 1))
    idxs = list(p.frame_slices((4, 2, 3), m=2))
    # first group advances along dim0 (first slice dim)
    assert idxs[0][0] == slice(0, 2)
    assert idxs[1][0] == slice(2, 4)
    # then dim1 increments
    assert idxs[2][1] == slice(1, 2)


def test_to_pspec():
    p = Pattern("P", core_dims=(1, 2), slice_dims=(0,))
    assert tuple(p.to_pspec("data")) == ("data", None, None)
    p2 = p.with_shard_axes({1: "model"})
    assert tuple(p2.to_pspec("data")) == ("data", "model", None)


def test_pattern_from_labels_and_dataset():
    ds = DataSet("tomo", (8, 6, 4), np.float32, ("theta", "y", "x"))
    pat = ds.add_pattern("SINOGRAM", core=("theta", "x"), slice_=("y",))
    assert pat.core_dims == (0, 2)
    assert pat.slice_dims == (1,)
    with pytest.raises(ValueError):
        pattern_from_labels("B", ("a", "b"), core=("zz",), slice_=("a",))
    with pytest.raises(KeyError):
        ds.get_pattern("NOPE")


def test_dataset_replacement_template():
    ds = DataSet("t", (4, 4), np.float32, ("a", "b"))
    ds.add_pattern("P", core=("a",), slice_=("b",))
    like = ds.like("t2")
    assert like.shape == ds.shape and "P" in like.patterns
    like2 = ds.like("t3", shape=(2, 2, 2), axis_labels=("x", "y", "z"))
    assert like2.patterns == {}
