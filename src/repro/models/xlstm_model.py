"""xLSTM LM assembly: groups of (slstm_every−1) mLSTM + 1 sLSTM blocks
(the released 7:1 recipe), scan-stacked per group kind."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ModelConfig, split_keys
from .layers import embed_tokens, init_embedding, rms_norm, unembed
from .remat import _remat_policy
from .sharding import get_rules, sp_residual
from .xlstm import (init_mlstm_block, init_mlstm_cache, init_slstm_block,
                    init_slstm_cache, mlstm_fwd, mlstm_step, slstm_fwd,
                    slstm_step)


def _layout(cfg: ModelConfig) -> tuple[int, int]:
    """(n_groups, mlstm_per_group). slstm_every==0 -> pure mLSTM."""
    if cfg.slstm_every == 0:
        return cfg.n_layers, 0
    assert cfg.n_layers % cfg.slstm_every == 0
    return cfg.n_layers // cfg.slstm_every, cfg.slstm_every - 1


def init_xlstm(key, cfg: ModelConfig) -> dict:
    g, m = _layout(cfg)
    ks = split_keys(key, 4)
    params: dict = {
        "embed": init_embedding(ks[0], cfg),
        "ln_f": jnp.ones((cfg.d_model,), cfg.param_dtype),
    }
    if cfg.slstm_every == 0:
        mk = jax.random.split(ks[1], g)
        params["mlstm"] = jax.vmap(lambda k: init_mlstm_block(k, cfg))(mk)
    else:
        mk = jax.random.split(ks[1], (g, m))
        params["mlstm"] = jax.vmap(jax.vmap(
            lambda k: init_mlstm_block(k, cfg)))(mk)
        sk = jax.random.split(ks[2], g)
        params["slstm"] = jax.vmap(lambda k: init_slstm_block(k, cfg))(sk)
    if not cfg.tie_embeddings:
        params["unembed"] = init_embedding(ks[3], cfg)
    return params


def xlstm_forward(params: dict, cfg: ModelConfig, *,
                  tokens: jnp.ndarray | None = None,
                  embeds: jnp.ndarray | None = None
                  ) -> tuple[jnp.ndarray, jnp.ndarray]:
    x = (embed_tokens(params["embed"], tokens, cfg.dtype)
         if embeds is None else embeds.astype(cfg.dtype))
    g, m = _layout(cfg)

    if cfg.slstm_every == 0:
        def body(x, layer):
            return sp_residual(x + mlstm_fwd(layer, x, cfg)), None
        xs = params["mlstm"]
    else:
        def body(x, group):
            mls, sls = group

            def inner(x, layer):
                return sp_residual(x + mlstm_fwd(layer, x, cfg)), None
            x, _ = jax.lax.scan(inner, x, mls)
            return sp_residual(x + slstm_fwd(sls, x, cfg)), None
        xs = (params["mlstm"], params["slstm"])

    step = body
    if cfg.remat:
        step = jax.checkpoint(body, policy=_remat_policy(cfg))
    x, _ = jax.lax.scan(step, x, xs)
    x = rms_norm(x, params["ln_f"].astype(cfg.dtype), cfg.norm_eps)
    table = params.get("unembed", params["embed"])
    return unembed(table, x), jnp.zeros((), jnp.float32)


# ----------------------------------------------------------------------
def init_xlstm_cache(cfg: ModelConfig, batch: int) -> dict:
    rules = get_rules()
    g, m = _layout(cfg)
    mc = init_mlstm_cache(cfg, batch)

    def pin(lead, a):
        # every cache leaf is (B, H, ...) after the stacked lead dims
        axes = [None] * len(lead) + ["batch", "heads"] + \
            [None] * (a.ndim - 2)
        return rules.constrain(jnp.broadcast_to(a, lead + a.shape), *axes)

    if cfg.slstm_every == 0:
        return {"mlstm": jax.tree.map(lambda a: pin((g,), a), mc),
                "length": jnp.zeros((), jnp.int32)}
    sc = init_slstm_cache(cfg, batch)
    return {
        "mlstm": jax.tree.map(lambda a: pin((g, m), a), mc),
        "slstm": jax.tree.map(lambda a: pin((g,), a), sc),
        "length": jnp.zeros((), jnp.int32),
    }


def xlstm_decode_step(params: dict, cfg: ModelConfig, token: jnp.ndarray,
                      cache: dict) -> tuple[jnp.ndarray, dict]:
    x = embed_tokens(params["embed"], token, cfg.dtype)
    g, m = _layout(cfg)

    if cfg.slstm_every == 0:
        def body(x, inp):
            layer, mc = inp
            y, mc_new = mlstm_step(layer, x, mc, cfg)
            return x + y, mc_new
        x, mc_new = jax.lax.scan(body, x, (params["mlstm"],
                                           cache["mlstm"]))
        new_cache = dict(cache, mlstm=mc_new, length=cache["length"] + 1)
    else:
        def body(x, inp):
            mls, mcs, sls, scs = inp

            def inner(x, inp2):
                layer, mc = inp2
                y, mc_new = mlstm_step(layer, x, mc, cfg)
                return x + y, mc_new
            x, mcs_new = jax.lax.scan(inner, x, (mls, mcs))
            y, scs_new = slstm_step(sls, x, scs, cfg)
            return x + y, (mcs_new, scs_new)
        x, (mc_new, sc_new) = jax.lax.scan(
            body, x, (params["mlstm"], cache["mlstm"], params["slstm"],
                      cache["slstm"]))
        new_cache = dict(cache, mlstm=mc_new, slstm=sc_new,
                         length=cache["length"] + 1)
    x = rms_norm(x, params["ln_f"].astype(cfg.dtype), cfg.norm_eps)
    table = params.get("unembed", params["embed"])
    return unembed(table, x), new_cache
