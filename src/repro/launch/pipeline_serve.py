"""Multi-dataset pipeline service driver — the paper's headline claim
("simultaneous processing of multiple ... datasets") as a running
service: submit N tomography jobs, process them over shared workers with
one compiled-plugin cache, report per-job status and aggregate
throughput, and verify every reconstruction against a serial
``PluginRunner`` reference.

    PYTHONPATH=src python -m repro.launch.pipeline_serve --jobs 4
    PYTHONPATH=src python -m repro.launch.pipeline_serve --jobs 8 \
        --workers 4 --batch --transport sharded
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
from jax.sharding import Mesh

from ..core import (ChunkedFileTransport, InMemoryTransport, PluginRunner,
                    ShardedTransport)
from ..service import (CheckpointStore, CompileCache, JobQueue,
                       PipelineScheduler)
from ..tomo import standard_chain


def _chain(args, seed: int):
    return standard_chain(n_det=args.n_det, n_angles=args.n_angles,
                          n_rows=args.n_rows, seed=seed,
                          use_pallas=args.pallas)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=4)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--transport", default="sharded",
                    choices=("sharded", "inmemory", "chunked"))
    ap.add_argument("--batch", action=argparse.BooleanOptionalAction,
                    default=False,
                    help="gang identical chains into one compiled call")
    ap.add_argument("--fuse", action=argparse.BooleanOptionalAction,
                    default=False)
    ap.add_argument("--verify", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="compare each job against a serial PluginRunner")
    ap.add_argument("--pallas", action=argparse.BooleanOptionalAction,
                    default=True)
    ap.add_argument("--n-det", type=int, default=48)
    ap.add_argument("--n-angles", type=int, default=48)
    ap.add_argument("--n-rows", type=int, default=2)
    ap.add_argument("--max-pending", type=int, default=64)
    ap.add_argument("--checkpoint-dir", default=None)
    args = ap.parse_args()

    cache = CompileCache()
    if args.transport == "sharded":
        mesh = Mesh(np.asarray(jax.devices()), ("data",))
        # gang batching stacks job inputs — donation would invalidate
        # buffers the stack still references.  Checkpointing no longer
        # forces donation off: the runner's liveness analysis donates a
        # buffer only at its FINAL use, so every dataset a checkpoint
        # (or a branching chain) still needs stays alive.
        donate = not args.batch

        def factory(job):
            return ShardedTransport(mesh, donate=donate,
                                    compile_cache=cache)
    elif args.transport == "chunked":
        def factory(job):
            return ChunkedFileTransport()
    else:
        def factory(job):
            return InMemoryTransport()

    queue = JobQueue(max_pending=args.max_pending)
    checkpoints = (CheckpointStore(args.checkpoint_dir)
                   if args.checkpoint_dir else None)
    sched = PipelineScheduler(
        queue, transport_factory=factory, n_workers=args.workers,
        checkpoints=checkpoints, batch_identical=args.batch,
        batch_max=args.jobs, fuse=args.fuse, compile_cache=cache)

    jobs = [queue.submit(_chain(args, seed=i), priority=0,
                         job_id=f"tomo-{i:03d}", metadata={"seed": i})
            for i in range(args.jobs)]
    t0 = time.time()
    sched.start()
    ok = sched.drain(timeout=600)
    wall = time.time() - t0
    sched.shutdown()
    if not ok:
        raise SystemExit("timed out waiting for jobs")

    failed = [j for j in jobs if j.state.value != "done"]
    for j in jobs:
        extra = (f" (resumed at plugin {j.resumed_from})"
                 if j.resumed_from else "")
        print(f"  {j.job_id}: {j.status:>10s}  wall={j.wall:.2f}s{extra}")
    if failed:
        for j in failed:
            print(j.metadata.get("traceback", j.error))
        raise SystemExit(f"{len(failed)}/{len(jobs)} jobs failed")

    if args.verify:
        worst = 0.0
        for j in jobs:
            ref = PluginRunner(_chain(args, seed=j.metadata["seed"])).run()
            got = j.runner.transport.read(j.runner.datasets["recon"])
            want = np.asarray(ref["recon"].materialise())
            np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)
            worst = max(worst, float(np.max(np.abs(got - want))))
        print(f"verified {len(jobs)} reconstructions against serial "
              f"PluginRunner (max |Δ|={worst:.2e})")

    st = sched.stats()
    print(f"{len(jobs)} jobs in {wall:.2f}s -> {len(jobs) / wall:.2f} "
          f"jobs/s  ({args.workers} workers, transport={args.transport}"
          f"{', gang-batched' if args.batch else ''})")
    print(f"compile cache: {cache.stats()}")
    if st.get("gangs_run"):
        print(f"gangs executed: {st['gangs_run']}")


if __name__ == "__main__":
    main()
