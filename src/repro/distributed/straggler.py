"""Straggler detection + mitigation hooks.

On a 1000-node job the common failure mode is not a crash but a slow
host (thermal throttle, ECC retry storm, a flaky ICI link).  The
monitor keeps a ring buffer of per-step wall times; a step slower than
``factor`` × the rolling median flags a straggler event.  Mitigation is
launcher policy, surfaced here as callbacks:

  * ``on_warn``  — log/emit (default),
  * ``on_evict`` — after ``patience`` consecutive slow steps the
    launcher should checkpoint + restart without the slow host (elastic
    restart path: CheckpointManager.restore with new mesh shardings).

Single-host container: exercised by tests with synthetic timings.
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from collections import deque
from typing import Callable


@dataclasses.dataclass
class StragglerEvent:
    step: int
    wall: float
    median: float
    ratio: float


class StragglerMonitor:
    def __init__(self, *, window: int = 32, factor: float = 2.0,
                 patience: int = 3,
                 on_warn: Callable[[StragglerEvent], None] | None = None,
                 on_evict: Callable[[StragglerEvent], None] | None = None):
        self.window = window
        self.factor = factor
        self.patience = patience
        self.on_warn = on_warn or (lambda e: None)
        self.on_evict = on_evict or (lambda e: None)
        self.times: deque[float] = deque(maxlen=window)
        self.events: list[StragglerEvent] = []
        self._consecutive = 0
        self._t0: float | None = None
        self._step = 0

    def start_step(self, step: int | None = None) -> None:
        self._step = step if step is not None else self._step + 1
        self._t0 = time.perf_counter()

    def end_step(self, wall: float | None = None) -> StragglerEvent | None:
        if wall is None:
            assert self._t0 is not None, "start_step not called"
            wall = time.perf_counter() - self._t0
        ev = self.observe(self._step, wall)
        self._t0 = None
        return ev

    def observe(self, step: int, wall: float) -> StragglerEvent | None:
        """Feed one step time; returns the event if it was slow."""
        med = statistics.median(self.times) if self.times else wall
        self.times.append(wall)
        if len(self.times) < 4 or med <= 0:
            return None
        ratio = wall / med
        if ratio >= self.factor:
            ev = StragglerEvent(step, wall, med, ratio)
            self.events.append(ev)
            self._consecutive += 1
            if self._consecutive >= self.patience:
                self.on_evict(ev)
            else:
                self.on_warn(ev)
            return ev
        self._consecutive = 0
        return None
