"""End-to-end telemetry: the cross-process trace and the /metrics
exposition, per the PR acceptance criteria.

Scheduler mode: a submitted job's ``GET /jobs/{id}/trace`` shows the
full timeline (queue.wait + per-plugin spans under one trace_id), the
ASCII gantt renders, ``GET /metrics`` is Prometheus-parseable and
carries every catalogued metric including ``job_latency_e2e``
quantiles, and ``/stats`` gains ``metrics``/``queue`` blocks.

Broker mode (the acceptance test): a job SIGKILLed mid-chain on one
worker and resumed on the survivor returns ONE contiguous timeline with
spans from BOTH worker_ids — the victim's history arrived via heartbeat
piggybacking before the kill, the broker's lease spans bracket both
attempts."""
import os
import signal
import time
import urllib.request

import pytest

import slow_plugins  # noqa: F401 — registers slow_identity server-side
from repro.obs import catalogue_names, prometheus_name
from repro.service import PipelineClient, PipelineService
from repro.service.worker import spawn_local_workers
from repro.tomo import standard_chain

TESTS_DIR = os.path.dirname(os.path.abspath(__file__))
N = dict(n_det=16, n_angles=8, n_rows=1)


@pytest.fixture
def service():
    """A scheduler-mode service (in-process workers) on an ephemeral
    port, plus its URL and a client."""
    svc = PipelineService(n_workers=2)
    host, port = svc.serve(port=0)
    url = f"http://{host}:{port}"
    client = PipelineClient(url, timeout=30.0)
    try:
        yield svc, client, url
    finally:
        svc.stop()


# ================================================= scheduler-mode trace
def test_trace_endpoint_scheduler_mode(service):
    svc, client, url = service
    jid = client.submit(standard_chain(**N, seed=0))
    snap = client.wait(jid, timeout=300)
    assert snap["state"] == "done", snap
    assert snap["trace_id"]

    wire = client.trace(jid)
    assert wire["job_id"] == jid
    assert wire["trace_id"] == snap["trace_id"]
    spans = wire["spans"]
    names = [s["name"] for s in spans]
    assert "queue.wait" in names
    # per-plugin process spans for the whole chain
    proc = [s for s in spans
            if s["name"].startswith("plugin.")
            and s.get("attrs", {}).get("phase") == "process"]
    assert len(proc) >= snap["n_plugins"]
    for s in spans:
        assert s["end"] is not None and s["end"] >= s["start"]
    # start-ordered: one contiguous timeline
    starts = [s["start"] for s in spans]
    assert starts == sorted(starts)

    # the Fig-9-style ASCII gantt
    text = client.trace(jid, text=True)
    assert "timeline" in text
    assert "queue.wait" in text and "#" in text

    # unknown job -> 404 (ServiceError from the client)
    from repro.service import ServiceError
    with pytest.raises(ServiceError):
        client.trace("no-such-job")


def test_metrics_endpoint_prometheus(service):
    svc, client, url = service
    jid = client.submit(standard_chain(**N, seed=1))
    assert client.wait(jid, timeout=300)["state"] == "done"

    with urllib.request.urlopen(f"{url}/metrics", timeout=30) as resp:
        ctype = resp.headers.get("Content-Type")
        text = resp.read().decode("utf-8")
    assert ctype == "text/plain; version=0.0.4; charset=utf-8"
    # every catalogued metric is exposed, even if never touched
    for name in catalogue_names():
        assert prometheus_name(name) in text, name
    # the acceptance metric: e2e latency quantiles from a real job
    assert 'job_latency_e2e{quantile="0.5"}' in text
    assert 'job_latency_e2e{quantile="0.99"}' in text
    assert "job_latency_e2e_count 1" in text
    assert "jobs_submitted 1" in text
    assert "jobs_completed 1" in text
    # parseable: every sample line is `name[{labels}] value`
    for line in text.strip().splitlines():
        if not line.startswith("#"):
            float(line.rpartition(" ")[2])

    # the client helper returns the same text
    assert "jobs_completed" in client.metrics()


def test_stats_carries_metrics_and_queue_age(service):
    svc, client, url = service
    st = client.stats()
    assert "metrics" in st and "queue" in st
    q = st["queue"]
    assert set(q) >= {"depth", "by_priority", "oldest_pending_age"}
    assert q["depth"] == 0 and q["oldest_pending_age"] is None
    jid = client.submit(standard_chain(**N, seed=2))
    client.wait(jid, timeout=300)
    snap = client.stats()["metrics"]
    assert snap["jobs.completed"] >= 1
    assert snap["job.latency.e2e"]["count"] >= 1
    assert snap["job.latency.e2e"]["p50"] > 0


# ============================================= broker-mode (acceptance)
def test_trace_spans_survive_kill_and_resume(tmp_path):
    """Kill the worker holding the lease mid-chain; after the job
    resumes and finishes on the second worker, ONE trace holds spans
    from BOTH worker ids: the victim's plugin spans (shipped by
    heartbeat before the kill), the broker's two lease spans (expired +
    done), and the survivor's resumed attempt."""
    ckpt = str(tmp_path / "ckpts")
    svc = PipelineService(workers_remote=True, lease_ttl=1.5,
                          sweep_interval=0.1)
    host, port = svc.serve(port=0)
    url = f"http://{host}:{port}"
    client = PipelineClient(url, timeout=60.0)
    spec = {"version": 1, "plugins": [
        {"plugin": "synthetic_tomo_loader",
         "params": {"n_det": 16, "n_angles": 8, "n_rows": 1, "seed": 5},
         "out_datasets": ["tomo"]},
        {"plugin": "dark_flat_correction", "params": {"use_pallas": False},
         "in_datasets": ["tomo"], "out_datasets": ["tomo"]},
        {"plugin": "slow_identity", "params": {"delay": 0.25},
         "in_datasets": ["tomo"], "out_datasets": ["tomo"]},
        {"plugin": "fbp_recon", "params": {"use_pallas": False},
         "in_datasets": ["tomo"], "out_datasets": ["recon"]},
        {"plugin": "hdf5_saver", "in_datasets": ["recon"]},
    ]}
    workers = spawn_local_workers(
        url, 2, transport="inmemory", checkpoint_dir=ckpt,
        poll=0.05, heartbeat=0.3, imports=("slow_plugins",),
        worker_ids=["w0", "w1"], pythonpath_extra=(TESTS_DIR,))
    by_id = dict(zip(["w0", "w1"], workers))
    try:
        jid = client.submit(spec, job_id="traced-crash-job")
        deadline = time.time() + 120
        while True:
            snap = client.status(jid)
            if snap["state"] == "running" and snap["plugin_index"] >= 1 \
                    and snap["worker_id"]:
                break
            assert snap["state"] not in ("done", "failed"), snap
            assert time.time() < deadline, f"never got mid-chain: {snap}"
            time.sleep(0.05)
        victim = snap["worker_id"]
        os.kill(by_id[victim].pid, signal.SIGKILL)

        snap = client.wait(jid, timeout=120)
        assert snap["state"] == "done", snap
        survivor = snap["worker_id"]
        assert survivor != victim and snap["attempt"] >= 2, snap

        wire = client.trace(jid)
        assert wire["trace_id"] == snap["trace_id"]
        spans = wire["spans"]
        # one contiguous, start-ordered timeline...
        starts = [s["start"] for s in spans]
        assert starts == sorted(starts)
        # ...with spans from BOTH distinct worker ids
        owners = {s.get("worker_id") for s in spans} - {None}
        assert {victim, survivor} <= owners, owners
        # the victim's pre-kill plugin history made it out via heartbeat
        victim_plugins = [s for s in spans
                         if s.get("worker_id") == victim
                         and s["name"].startswith("plugin.")]
        assert victim_plugins, [s["name"] for s in spans]
        # the broker bracketed both attempts with lease spans
        leases = [s for s in spans if s["name"] == "lease"]
        assert len(leases) >= 2
        outcomes = {s["attrs"]["outcome"] for s in leases}
        assert "expired" in outcomes and "done" in outcomes
        assert {s["worker_id"] for s in leases} == {victim, survivor}
        # the survivor's attempt span records the retry number
        attempts = [s for s in spans if s["name"] == "attempt"]
        assert any(s.get("worker_id") == survivor
                   and s["attrs"]["attempt"] >= 2 for s in attempts)

        # the gantt renders the cross-worker story
        text = client.trace(jid, text=True)
        assert "timeline" in text and victim in text and survivor in text

        # lease-expiry accounting reached the metrics registry
        snap_m = client.stats()["metrics"]
        assert snap_m["lease.expired"] >= 1
        assert snap_m["jobs.requeued"] >= 1
        assert "lease_expired" in client.metrics()
    finally:
        for p in workers:
            if p.poll() is None:
                p.kill()
        for p in workers:
            p.wait(timeout=10)
        svc.stop()
