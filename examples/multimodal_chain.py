"""Fig-10 reproduction: a multi-modal mapping-tomography chain.

Two loaders create 'absorb' and 'fluo' datasets; the fluorescence data
is corrected *using* the absorption data (2-in plugin), then both are
reconstructed — multiple datasets alive simultaneously, each with its
own processing history, exactly the paper's multi-modal story.

    PYTHONPATH=src python examples/multimodal_chain.py
"""
import numpy as np

import jax.numpy as jnp

from repro.core import (BaseLoader, BasePlugin, BaseSaver, DataSet,
                        InMemoryTransport, PluginRunner, ProcessList,
                        PROJECTION, SINOGRAM)
from repro.tomo import (FBPRecon, ParallelGeometry, SinogramFilter,
                        forward_project, phantom_stack)


class MappingLoader(BaseLoader):
    """Simulates a mapping scan: absorption (3-D) + fluorescence (3-D,
    here one emission channel of a 4-D stack)."""
    name = "mapping_loader"
    parameters = {"n_det": 48, "n_angles": 72, "kind": "absorb"}

    def load(self):
        p = self.params
        geom = ParallelGeometry(p["n_angles"], p["n_det"], 2)
        vol = phantom_stack(p["n_det"], 2)
        if p["kind"] == "fluo":
            vol = np.roll(vol, 3, axis=1) * 0.7    # different contrast
        proj = forward_project(vol, geom).astype(np.float32)
        ds = DataSet(self.out_dataset_names[0], proj.shape, np.float32,
                     ("rotation_angle", "detector_y", "detector_x"),
                     backing=proj)
        ds.add_pattern(PROJECTION, core=("detector_y", "detector_x"),
                       slice_=("rotation_angle",))
        ds.add_pattern(SINOGRAM, core=("rotation_angle", "detector_x"),
                       slice_=("detector_y",))
        ds.metadata.update({"geometry": geom, "mu": 1.0, "truth": vol})
        return [ds]


class AbsorptionCorrection(BasePlugin):
    """Correct fluorescence by absorption attenuation (2-in, 1-out) —
    the multi-dataset plugin type from paper §II.B."""
    name = "absorption_correction"
    n_in_datasets = 2
    n_out_datasets = 1

    def setup(self, ins):
        absorb, fluo = ins
        dout = fluo.like(self.out_dataset_names[0])
        dout.metadata = dict(fluo.metadata)
        self.chunk_frames(PROJECTION, 1)
        return [dout]

    def process_frames(self, frames):
        absorb, fluo = frames
        atten = jnp.exp(-0.01 * absorb)
        return fluo / jnp.maximum(atten, 0.1)


class PrintSaver(BaseSaver):
    name = "print_saver"

    def save(self, ds):
        arr = np.asarray(ds.materialise())
        print(f"  saved {ds.name}: shape={arr.shape} "
              f"range=({arr.min():.2f}, {arr.max():.2f}) "
              f"produced_by={ds.produced_by}")


def main():
    pl = ProcessList()
    pl.add(MappingLoader, params={"kind": "absorb"},
           out_datasets=("absorb",))
    pl.add(MappingLoader, params={"kind": "fluo"}, out_datasets=("fluo",))
    # fluo corrected using absorb (both alive simultaneously)
    pl.add(AbsorptionCorrection, in_datasets=("absorb", "fluo"),
           out_datasets=("fluo",))
    # each dataset then gets its own recon path
    for name in ("absorb", "fluo"):
        pl.add(SinogramFilter, in_datasets=(name,), out_datasets=(name,))
        pl.add(FBPRecon, in_datasets=(name,),
               out_datasets=(f"{name}_vol",))
    pl.add(PrintSaver, in_datasets=("absorb_vol",))
    pl.add(PrintSaver, in_datasets=("fluo_vol",))

    runner = PluginRunner(pl, InMemoryTransport())
    print("running multi-modal chain (Fig 10):")
    runner.run()
    print()
    print(runner.profiler.report())


if __name__ == "__main__":
    main()
